"""Assemble EXPERIMENTS.md from run artifacts:

  experiments/dryrun/       baseline dry-run JSONs (paper-faithful stack)
  experiments/dryrun_opt/   optimized dry-run JSONs (post §Perf changes)
  experiments/perf/         hillclimb iteration JSONs
  experiments/results/      FL benchmark JSONs (paper tables/figures)
  experiments/trace/        obs.Telemetry artifacts (per-round metrics.jsonl
                            + events.jsonl, from --trace-dir runs or
                            benchmarks.telemetry_smoke)

  PYTHONPATH=src python -m benchmarks.make_experiments_md > EXPERIMENTS.md

As a side effect the telemetry section is also written standalone to
``experiments/README.md`` -- the per-round observability digest (bytes/
round timeline, staleness histogram) next to the raw artifacts it renders.
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(ROOT, d, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if "arch" in r:
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def _results(name):
    p = os.path.join(ROOT, "experiments", "results", f"{name}.json")
    if os.path.exists(p):
        with open(p) as f:
            r = json.load(f)
        r.pop("_meta", None)        # run-env envelope (for the perf gate)
        return r
    return None


def dryrun_table(recs, mesh):
    lines = [
        "| arch | shape | status | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | 6ND/HLO | peak GB/dev | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r["status"] == "ok":
            rl = r["roofline"]
            peak = r["memory"]["peak_estimate_gb"]
            fits = "yes" if peak <= 16.0 else "**no**"
            lines.append(
                f"| {arch} | {shape} | ok | {rl['compute_s']*1e3:.1f} "
                f"| {rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} "
                f"| {rl['dominant']} | {rl['useful_ratio']:.2f} | {peak:.2f} | {fits} |")
        else:
            why = r.get("skip_reason", r.get("error", ""))[:70]
            lines.append(f"| {arch} | {shape} | {r['status']} | | | | | | | {why} |")
    return "\n".join(lines)


def fmt(v, nd=4):
    return f"{v:.{nd}f}" if isinstance(v, (int, float)) and v is not None else str(v)


# ----------------------------------------------------------------------
# Telemetry digest: per-round metrics from obs.Telemetry artifacts
# ----------------------------------------------------------------------

def _load_metrics_rows(arm_dir):
    p = os.path.join(arm_dir, "metrics.jsonl")
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return [json.loads(line) for line in f if line.strip()]


def _staleness_bars(hist):
    """De-cumulate a Prometheus-style ``le_*`` histogram sample into
    per-bucket counts with ASCII bars."""
    bounds = sorted((k for k in hist if k.startswith("le_") and
                     k != "le_inf"), key=lambda k: float(k[3:]))
    lines, prev = [], 0
    total = hist.get("count", 0) or 1
    for k in bounds + ["le_inf"]:
        n = hist[k] - prev
        prev = hist[k]
        label = "+Inf" if k == "le_inf" else k[3:]
        bar = "#" * round(24 * n / total)
        lines.append(f"    s <= {label:>4}  {n:>6}  {bar}")
    return lines


def telemetry_md():
    """Markdown digest of ``experiments/trace/<arm>/metrics.jsonl``:
    the WAN bytes/round timeline and the commit-staleness histogram."""
    trace_root = os.path.join(ROOT, "experiments", "trace")
    arms = sorted(d for d in glob.glob(os.path.join(trace_root, "*"))
                  if os.path.isdir(d))
    out = ["## §Telemetry — per-round observability digest", "",
           "Rendered from `experiments/trace/<arm>/metrics.jsonl` "
           "(`obs.Telemetry` artifacts; regenerate with "
           "`PYTHONPATH=src python -m benchmarks.telemetry_smoke "
           "--out experiments/trace` or any bench run under "
           "`--trace-dir`). Counters are cumulative ledgers mirrored "
           "exactly (`astraea_wan_bytes_total` **is** "
           "`CommMeter.total_bytes`); the span timeline for each arm "
           "lives next door in `events.jsonl` / `trace.json` "
           "(Perfetto-loadable).", ""]
    if not arms:
        out.append("*(no trace artifacts found -- run the smoke tool "
                   "above to populate this section)*")
        return "\n".join(out)
    for arm_dir in arms:
        rows = _load_metrics_rows(arm_dir)
        if not rows:
            continue
        arm = os.path.basename(arm_dir)
        out += [f"### {arm}", "",
                "| round | WAN bytes (cum) | Δ bytes | intra-pod bytes "
                "| traces | commits |",
                "|---|---|---|---|---|---|"]
        prev_wan = 0
        for r in rows:
            wan = r.get("astraea_wan_bytes_total", 0)
            out.append(
                f"| {r['round']} | {int(wan):,} | {int(wan - prev_wan):,} "
                f"| {int(r.get('astraea_intra_pod_bytes_total', 0)):,} "
                f"| {int(r.get('astraea_round_traces', 0))} "
                f"| {int(r.get('astraea_commits_total', 0))} |")
            prev_wan = wan
        hist = rows[-1].get("astraea_staleness")
        if hist and hist.get("count"):
            out += ["", "Commit staleness distribution (all rounds):", "",
                    "```"] + _staleness_bars(hist) + ["```"]
        out.append("")
    return "\n".join(out)


def lora_md():
    """Markdown digest of ``experiments/results/lora.json`` (the
    ``--only lora`` rank sweep): adapter WAN bytes vs the full-delta
    oracle, with the bitwise acceptance booleans."""
    r = _results("lora")
    out = ["## §LoRA — adapter-delta WAN exchange vs rank", "",
           "Rendered from `experiments/results/lora.json` (regenerate "
           "with `PYTHONPATH=src python -m benchmarks.run --only lora`; "
           "diffed by the perf gate — bytes exact, times ratio-gated). "
           "Astraea engine, c=8 γ=4 E_m=1 on the tiny letterfreq "
           "federation; per-round legs = 2·c·E_m + 2·⌈c/γ⌉ = 20, each "
           "shipping the adapter state instead of the full model. Frozen "
           "A bases are seed-derived and never on the wire "
           "(`src/repro/models/README.md`).", ""]
    if not r:
        out.append("*(no lora.json found -- run the bench above)*")
        return "\n".join(out)
    full = r.get("full_delta", {})
    out += ["| arm | adapter params | WAN bytes/round | adapter/full "
            "| us/round | traces | invariants |",
            "|---|---|---|---|---|---|---|",
            f"| full-delta oracle | (all) "
            f"| {int(full.get('wan_bytes_per_round', 0)):,} | 1.0000 "
            f"| {full.get('us_per_round', 0):,.0f} "
            f"| {full.get('traces', '')} | — |"]
    for name in sorted((k for k in r if k.startswith("rank")
                        and isinstance(r[k], dict)),
                       key=lambda k: int(k[4:])):
        row = r[name]
        inv = [k for k in ("ledger_exact", "rank0_frozen",
                           "rank2_ratio_le_0p10", "full_rank_bitwise")
               if row.get(k)]
        out.append(
            f"| {name} | {row['adapter_params']:,} "
            f"| {int(row['wan_adapter_bytes_per_round']):,} "
            f"| {row['ratio']:.4f} | {row['us_per_round']:,.0f} "
            f"| {row['traces']} | {', '.join(inv) or '—'} |")
    out += ["",
            f"Full rank for this CNN is {r.get('full_rank')}: every "
            "mapping entry degenerates to a dense effective tensor, so "
            "the `full_rank_bitwise` arm equals the full-delta oracle "
            "BITWISE after identical rounds (and ships identical bytes). "
            "`rank2` is the acceptance config: ≤10% of the full-delta "
            "WAN bytes with the ledger matching the closed form "
            "exactly."]
    return "\n".join(out)


def write_experiments_readme():
    path = os.path.join(ROOT, "experiments", "README.md")
    with open(path, "w") as f:
        f.write("# experiments/ — run artifacts\n\n"
                "`results/` holds the FL benchmark JSONs diffed by the CI "
                "perf gate (`benchmarks/gate.py`); `trace/` holds "
                "`obs.Telemetry` round-trace artifacts (events.jsonl, "
                "Perfetto trace.json, metrics.jsonl, metrics.prom). This "
                "file is generated by `benchmarks.make_experiments_md` -- "
                "do not edit by hand.\n\n")
        f.write(lora_md())
        f.write("\n\n")
        f.write(telemetry_md())
        f.write("\n")
    return path


def main():
    base = _load("experiments/dryrun")
    opt = _load("experiments/dryrun_opt")

    print("""# EXPERIMENTS — Astraea (ICCD 2019) reproduction + TPU-pod engineering

All FL numbers are from the CPU-scaled synthetic analogues (DESIGN.md §2);
paper values quoted for reference are at the paper's own scale, so we
validate *directions and mechanisms* quantitatively at our scale, not the
paper's exact percentages. Dry-run/roofline numbers are per-device from
compiled XLA programs for TPU v5e meshes (197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s ICI).

## §Claims — paper vs. this reproduction
""")
    mot = _results("motivation") or {}
    acc_e = _results("accuracy_emnist") or {}
    acc_c = _results("accuracy_cinic") or {}
    kld = _results("kld") or {}
    comm = _results("communication") or {}
    alpha = _results("alpha_sweep") or {}

    rows = [
        ("Global imbalance degrades FedAvg (Fig. 1a)",
         "−7.92% (INS→LTRF1)",
         f"−{100*(mot.get('INS',0)-mot.get('LTRF1',0)):.1f}% (INS→LTRF1)"
         if mot else "run benchmarks"),
        ("Local/size imbalance alone does not degrade (Fig. 1a)",
         "BAL≈INS (INS slightly higher)",
         f"BAL2 {100*mot.get('BAL2',0):.1f}% vs INS {100*mot.get('INS',0):.1f}%"
         if mot else ""),
        ("Astraea beats FedAvg on imbalanced EMNIST (Fig. 4)",
         "+5.59%",
         f"+{100*(acc_e.get('astraea',0)-acc_e.get('fedavg',0)):.1f}%" if acc_e else ""),
        ("Astraea beats FedAvg on imbalanced CINIC-10 (Fig. 5)",
         "+5.89%",
         f"+{100*(acc_c.get('astraea',0)-acc_c.get('fedavg',0)):.1f}%" if acc_c else ""),
        ("Augmentation alone < aug+mediators (Fig. 4/5)",
         "ordering holds",
         f"aug {100*acc_e.get('aug_only',0):.1f}% < full "
         f"{100*acc_e.get('astraea',0):.1f}%" if acc_e else ""),
        ("Classical cost-sensitive reweighting < Astraea (beyond-paper ablation)",
         "not evaluated by the paper",
         (f"FedAvg+inv-freq loss {100*(acc_e.get('fedavg_reweighted') or 0):.1f}% "
          f"vs Astraea {100*acc_e.get('astraea',0):.1f}% — reweighting "
          f"rebalances gradients but adds no minority information (Alg. 2) "
          f"and leaves local imbalance (Alg. 3) untouched")
         if acc_e.get("fedavg_reweighted") else "run benchmarks"),
        ("Mediator KLD mean < 0.2 after rescheduling (Fig. 7)",
         "0.550 → 0.125",
         (lambda ks: f"{kld.get('fedavg',0):.3f} → " +
          ", ".join(f"{kld[k]:.3f}" for k in ks) if kld else "")(
              [k for k in kld if k.startswith("c")])),
        ("α=2 over-augments and hurts (Fig. 4a/9)",
         "accuracy collapse at α=2",
         (f"α=0.67: {100*alpha.get('0.67',{}).get('acc',0):.1f}% vs "
          f"α=2: {100*alpha.get('2.00',{}).get('acc',0):.1f}%") if alpha else ""),
        ("Astraea reaches target accuracy with less traffic (Tab. III)",
         "0.18–0.24× bytes (FedAvg crawls ~226 rounds to 75%)",
         (f"0.45× sync rounds (Med2: {comm.get('med2_rounds')} vs FedAvg "
          f"{comm.get('fedavg_rounds')}); bytes ratio flips to "
          f"{comm.get('med2_mb',0)/comm.get('fedavg_mb',1):.1f}× at CPU scale "
          f"because FedAvg converges in ~25 cheap rounds here — the paper's "
          f"bytes win needs its 500-client crawl regime (mechanism = fewer "
          f"rounds reproduces; see benchmarks.run.bench_communication)")
         if comm.get("med2_rounds") else "see table"),
        ("E_m=2 improves accuracy over E_m=1 at E=1 (Fig. 8)",
         "+1.4%",
         (lambda ep: f"+{100*(ep.get('E1_Em2',0)-ep.get('E1_Em1',0)):.1f}%"
          if ep else "")(_results("epochs") or {})),
        ("Larger c improves Astraea accuracy (Fig. 6)",
         "accuracy rises with c",
         (lambda cg: " / ".join(f"{k}={100*v:.0f}%" for k, v in sorted(cg.items()))
          if cg else "")(_results("c_gamma") or {})),
    ]
    print("| claim | paper | ours |")
    print("|---|---|---|")
    for a, b, c in rows:
        print(f"| {a} | {b} | {c} |")

    print("""
Raw benchmark CSVs: `bench_output.txt` (regenerate with
`PYTHONPATH=src python -m benchmarks.run`); per-table JSON in
`experiments/results/`.

## §Dry-run

Every (architecture × input shape) lowered AND compiled with
`ShapeDtypeStruct` inputs on both production meshes; `skipped` rows are the
documented long_500k exclusions for pure full-attention architectures
(DESIGN.md §5). `peak GB/dev` is `memory_analysis()`
(args + temps + outs − aliased); `6ND/HLO` is useful-FLOPs ratio
(model 6·N·D / compiled HLO FLOPs, trip-count-corrected).

### Baseline (paper-faithful stack) — single pod 16×16 (256 chips)
""")
    print(dryrun_table(base, "single16x16"))
    print("\n### Baseline — multi-pod 2×16×16 (512 chips)\n")
    print(dryrun_table(base, "pod2x16x16"))
    print("""
### Optimized stack (post-§Perf: blockwise attention, SP residuals,
### token-parallel tiny-expert MoE) — single pod
""")
    print(dryrun_table(opt, "single16x16"))
    print("\n### Optimized — multi-pod\n")
    print(dryrun_table(opt, "pod2x16x16"))

    # ---- fl_round table
    fl = []
    for pth in sorted(glob.glob(os.path.join(ROOT, "experiments/fl_round/*.json"))):
        with open(pth) as f:
            fl.append(json.load(f))
    if fl:
        print("""
### Astraea federated round on the mesh (the paper's technique, one XLA program)

`make_fl_round`: 16 mediators (data axis) x 16-way tensor parallel (model
axis, compiler-auto inside jax.shard_map), each mediator running its
scheduled clients' token streams as sequential SGD steps, aggregated with
the Eq. 6 weighted delta all-reduce. Lowered + compiled for the full
configs on the single-pod mesh (train_4k shape):

| arch | status | compute (s) | memory (s) | collective (s) | peak GB/dev |
|---|---|---|---|---|---|""")
        for r in fl:
            if r.get("status") == "ok":
                rl = r["roofline"]
                print(f"| {r['arch']} | ok | {rl['compute_s']:.2f} "
                      f"| {rl['memory_s']:.2f} | {rl['collective_s']:.2f} "
                      f"| {r['memory']['peak_estimate_gb']:.1f} |")
            else:
                print(f"| {r['arch']} | {r.get('status')} | | | | "
                      f"{r.get('error','')[:60]} |")
        print("""
Notes: the FL round holds per-mediator weight replicas and runs B/16
sequential local steps, so its memory term is ~2-3x a centralized train
step -- the on-mesh cost of the paper's E_m*gamma*E x T time-overhead
model (§IV-C). Two XLA-CPU findings are documented in the code: bf16
psum under partial-auto shard_map crashes the CPU backend (worked around
by aggregating deltas in f32 -- also numerically preferable), and
activation sharding constraints must not mention the manual mediator
axes.""")

    # ---- before/after summary
    print("""
### Baseline → optimized, step-time bound (max of the three terms)

| arch | shape | baseline bound (s) | optimized bound (s) | Δ | baseline peak GB | optimized peak GB |
|---|---|---|---|---|---|---|""")
    for key in sorted(base):
        arch, shape, mesh = key
        if mesh != "single16x16":
            continue
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        bb = max(b["roofline"]["compute_s"], b["roofline"]["memory_s"],
                 b["roofline"]["collective_s"])
        ob = max(o["roofline"]["compute_s"], o["roofline"]["memory_s"],
                 o["roofline"]["collective_s"])
        print(f"| {arch} | {shape} | {bb:.2f} | {ob:.2f} | {bb/ob:.2f}x "
              f"| {b['memory']['peak_estimate_gb']:.1f} "
              f"| {o['memory']['peak_estimate_gb']:.1f} |")

    print(PERF_NARRATIVE)
    print()
    print(lora_md())
    print()
    print(telemetry_md())
    write_experiments_readme()


PERF_NARRATIVE = r"""
## §Roofline — reading the table

* Hardware: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
* FLOPs/bytes/collective bytes are parsed from the post-SPMD HLO with
  while-loop trip accounting (`repro.roofline.hlo`); XLA's own
  `cost_analysis()` counts scan bodies once and is reported in the JSONs
  for comparison. Fusion operands that are only dynamic-sliced inside the
  fused computation are charged at slice size (stacked layer weights).
* **Attribution caveat** (found during §Perf H5): collectives inside the
  microbatch-accumulation loop are multiplied by both loop trip counts;
  the microbatch sweep on grok (4->1 changed the collective term only
  -14%) shows the dominant weight-gradient reductions are amortized
  across microbatches by XLA, so the collective terms for microbatched
  train rows are upper bounds (up to ~4x for grok). Both bounds are noted
  where it changes the dominant term.
* Dominant bottleneck per family (baseline, single pod):
  - **dense/MoE train_4k**: collective-bound -- per-layer f32
    tensor-parallel + FSDP collectives (see §Perf).
  - **prefill_32k**: memory-bound -- attention score materialization
    (fixed by H4) and fp32 logits over 100k+ vocabularies.
  - **decode_32k**: collective-bound at tiny compute -- decode is
    latency/bandwidth dominated, as expected at batch 128 with 4-8 GB
    KV caches per device.
  - **SSM (mamba2) everywhere**: memory-bound; the SSD scan has
    useful-ratio ~1 at decode (it is pure streaming) -- the healthiest
    rows in the table.
* MODEL_FLOPS / HLO_FLOPs ("6ND/HLO"): train rows sit at 0.56-0.78
  (remat + attention not counted in 6ND); prefill rows at 32k drop to
  0.3 because the quadratic attention term dominates 2ND; MoE rows carry
  the capacity-factor overhead (1.25x) plus dispatch einsums.

## §Perf — hypothesis -> change -> measure log

Three hillclimbed pairs: worst useful-ratio (granite-moe x train_4k),
most collective-bound (grok-1 x train_4k), most representative of the
paper's technique (qwen3-4b x train_4k -- the federated-LM target, plus
the fl_round lowering). Step-time bound = max(compute, memory,
collective) per step per device. Baselines from `experiments/dryrun`,
optimized from `experiments/dryrun_opt`, iterations in
`experiments/perf/`.

### Bring-up fixes (pre-baseline, recorded for honesty)
Naive pjit with parameter shardings only produced replicated fp32 logits
and unsharded scan carries: whisper train peaked at 811 GB/device and
grok at 165 GB/device. Three structural fixes define the recorded
baseline: MaxText-style logical activation constraints (batch/heads/
vocab/mlp/expert), sequence-parallel residual storage for the scan carry
(Megatron-SP; the 96 GB f32 carry-stack convert XLA hoisted out of the
backward loop shrank 16x), and gradient-accumulation microbatching sized
by napkin math (`suggest_microbatches`). whisper 811->3.5 GB, grok
165->30.9 GB.

### granite-moe-3b-a800m x train_4k  (40.4 s -> 5.6 s bound, 7.2x; peak 29.0 -> 7.2 GB)
| iter | hypothesis | prediction | measured | verdict |
|---|---|---|---|---|
| base | -- | -- | comp 0.74 / mem 12.97 / coll 40.40 s; useful 0.15 | collective-bound |
| H3 | 512-wide experts / 16-way TP = 32-wide MXU-hostile matmuls + dispatch all-to-all dominates; replicating expert weights over "model" removes the A2A | coll ~/2 | coll 20.28, but comp 0.74->2.15 (16x redundant expert compute) | confirmed direction, refine |
| H3b | also shard token *groups* over (data x model): expert compute parallel again, dispatch stays local | comp back down, coll ~/4 | comp 0.30 / mem 6.76 / coll 5.69 | **confirmed, adopted** (`moe_token_parallel=True` in the config) |
| +H4 | blockwise attention (below) | mem down | mem 5.62 / coll 5.33, peak 7.2 GB | confirmed |

Lesson: for tiny-expert MoEs (d_ff << 128*TP), expert-parallelism is the
wrong decomposition on a 16-wide TP mesh; data x model *token*
parallelism with replicated experts is strictly better until d_ff/TP
reaches MXU width.

### grok-1-314b x train_4k  (150.8 -> 138.3 s bound; peak 30.9 -> 29.7 GB single-pod, 21.9 GB multi-pod)
| iter | hypothesis | prediction | measured | verdict |
|---|---|---|---|---|
| base | -- | -- | comp 18.7 / mem 65.2 / coll 150.8 s | collective-bound |
| H1 | fp32 grad accumulators replicated -> per-microbatch full-size all-reduce; pin them to param shardings | coll down several x | bit-identical lowering | **refuted** -- already sharded |
| H2 | per-layer weight cotangents replicated; custom_vjp identity pinning inside the scan body | reduce-scatter instead of AR | bit-identical lowering | **refuted** -- shardy had already reconciled placement |
| H4 | blockwise attention removes (S,S) scores | mem down, coll slightly down | mem 65.2->56.1, coll 150.8->138.3 | **confirmed, adopted** (-8.5% bound) |
| H5 | weight-grad reduces are per-microbatch; mb 4->1 cuts coll ~4x | coll /4 | coll -14%, peak 29.7->50.2 GB | **refuted** -- reduces amortized across microbatches; also exposed the trip-attribution caveat (§Roofline) |
| H6 | MoE group 512->2048 improves dispatch arithmetic intensity | coll/mem down | no change | refuted |
| H7 | force SP reduce-scatter on block outputs before residual adds | AR(2x) -> RS(1x) | bit-identical lowering | refuted -- already chosen |
| stop | 3 consecutive <5% changes | | | per §Perf stopping rule |

Lesson: grok's wall is the *dtype* of per-layer collectives -- XLA-CPU
materializes gather/reduce of the bf16 stream in f32 (norm/softmax
upcast chains get hoisted). Halving that needs compiler-level collective
dtype pinning (or Mosaic collective kernels on real TPUs), not the
sharding-constraint API; identified as the next-step item. Grok train
also genuinely does not fit 16 GB/chip on a single v5e pod (params+Adam
floor ~12 GB + transients); the 512-chip multi-pod with FSDP over
(pod, data) is the deployable configuration (21.9 GB -> still needs
either 2 more FSDP-able dims or bf16 moments+master-free Adam; recorded
as an open item).

### qwen3-4b x train_4k  (15.8 -> 11.0 s bound, 1.43x; peak 15.1 -> 11.7 GB)
| iter | hypothesis | prediction | measured | verdict |
|---|---|---|---|---|
| base | -- | -- | comp 0.82 / mem 12.51 / coll 15.78 s | collective-bound |
| H4 | blockwise (flash) attention: stream KV blocks with online softmax, checkpointed block bodies | mem -30%, transient scores gone | mem 9.10 / coll 11.00, peak 11.7 GB | **confirmed, adopted** |
| H7 | SP reduce-scatter residuals | coll down | bit-identical | refuted (already chosen) |
| fl | lower the Astraea round itself (16 mediators x TP16, 64 sequential local steps) | round ~ E_m*gamma*E x T of a train step (paper §IV-C) | comp 0.68 / mem 29.2 / coll 7.08 s, peak 18.8 GB | the paper's time-overhead model quantified on the mesh |

### H8 — exact local-window attention for SWA architectures
The first blockwise rollout REGRESSED hymba prefill_32k 7.0 -> 109 s
(memory term): the KV-block scan streams all 64 blocks while the 1024-wide
window only ever needs 2 -- and the scan re-reads the full q per block.
Hypothesis: sliding-window attention chunked AT the window size is exact
with just a (W, 2W) score block per chunk (keys in chunks i-1, i).
Measured: hymba prefill bound 109 -> 3.3 s (and 2.1x better than the
paper-faithful baseline), peak 25.9 -> 2.7 GB; h2o-danube prefill
5.9 -> 2.0 s, peak 34.1 -> 9.1 GB. Confirmed, adopted (the `gqa_attention`
dispatcher routes SWA prefill/train to `local_window_attention`).
A refuted-then-fixed iteration: the regression was caught by the
before/after table, diagnosed from the traffic model (q re-reads x
n_blocks), and the fix beat the original baseline.

### Beyond-paper wins recorded in the optimized sweep
* H4 blockwise attention is default for full-attention prefill at seq >=
  2048: qwen1.5-110b prefill_32k peak 71.4 -> 11.3 GB (now *fits*),
  internvl2 115 -> 2.3 GB, qwen3 34.7 -> 3.0 GB, grok 59.8 -> 21.2 GB.
  The memory TERM rises ~20% on those rows (q is re-streamed once per KV
  block -- inherent to any flash scheme; one operand re-streams
  O(n_blocks) times) -- an intentional trade, since the dense baselines
  exceed HBM and could not run at all. For TRAIN at 4k, the trade is
  taken per-arch (H9, `blockwise_train`): measured wins for
  qwen3/grok/h2o/hymba/granite/qwen1.5, measured regressions -> disabled
  for gemma/internvl2/whisper (their dense 4k scores already fit).
* H3b token-parallel MoE is default for granite (tiny experts). grok
  keeps expert-sharded MoE (d_ff/TP = 2048 is MXU-healthy).
* The Pallas `flash_attention` kernel is the TPU-native version of H4
  (same algorithm, VMEM tiles + MXU-aligned blocks), validated
  interpret=True against `ref.py`; on real hardware it replaces the XLA
  scan emulation.
"""


if __name__ == "__main__":
    main()
