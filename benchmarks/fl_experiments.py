"""Shared experiment harness for the paper's tables/figures (CPU-scaled).

Scale knobs live in ``Scale``; the default finishes each experiment in a
couple of minutes on CPU while preserving every *structural* property of
the paper's setup (TABLE I partitions, CNN families, Adam on clients,
balanced test set). ``--full`` in run.py doubles everything.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.core.fedavg import FedAvgTrainer
from repro.data.federated import partition, EMNIST_LIKE, CINIC_LIKE
from repro.models.cnn import emnist_cnn, cinic_cnn
from repro.optim import adam


@dataclass(frozen=True)
class Scale:
    num_clients: int = 20
    total_samples: int = 2400
    test_samples: int = 800
    rounds: int = 12
    eval_every: int = 3
    c: int = 10                 # online clients / round
    gamma: int = 5
    batch: int = 20
    local_epochs: int = 2
    image: int = 16
    classes: int = 10


DEFAULT = Scale()
FULL = Scale(num_clients=40, total_samples=6000, test_samples=1500, rounds=30,
             eval_every=5, c=16, gamma=8)


def emnist_spec(scale: Scale):
    return dataclasses.replace(EMNIST_LIKE, num_classes=scale.classes,
                               image_size=scale.image, noise=0.45, distort=0.35)


def cinic_spec(scale: Scale):
    return dataclasses.replace(CINIC_LIKE, num_classes=10,
                               image_size=max(scale.image, 16),
                               noise=0.5, distort=0.35)


def make_fed(spec, scale: Scale, *, sizes="instagram", global_dist="letterfreq",
             local="random", seed=0, name="fed", total_mult=1.0):
    return partition(spec, num_clients=scale.num_clients,
                     total_samples=int(scale.total_samples * total_mult),
                     test_samples=scale.test_samples, sizes=sizes,
                     global_dist=global_dist, local=local, seed=seed, name=name)


def model_for(spec, scale: Scale, kind: str = "emnist"):
    if kind == "cinic":
        return cinic_cnn(spec.num_classes, image_size=spec.image_size, width=16)
    return emnist_cnn(spec.num_classes, image_size=spec.image_size)


def run_fedavg(model, fed, scale: Scale, *, seed=0, local_epochs=None):
    tr = FedAvgTrainer(model, adam(1e-3), fed, clients_per_round=scale.c,
                       local=LocalSpec(scale.batch, local_epochs or scale.local_epochs),
                       seed=seed)
    hist = tr.fit(scale.rounds, eval_every=scale.eval_every)
    return tr, hist


def run_astraea(model, fed, scale: Scale, *, alpha=0.67, mediator_epochs=1,
                gamma=None, c=None, seed=0, local_epochs=None, use_kernel=False,
                aug_mode="online"):
    tr = AstraeaTrainer(model, adam(1e-3), fed,
                        clients_per_round=c or scale.c, gamma=gamma or scale.gamma,
                        local=LocalSpec(scale.batch, local_epochs or scale.local_epochs),
                        mediator_epochs=mediator_epochs, alpha=alpha, seed=seed,
                        use_kernel_agg=use_kernel, aug_mode=aug_mode)
    hist = tr.fit(scale.rounds, eval_every=scale.eval_every)
    return tr, hist


def best_acc(hist) -> float:
    return max(h["accuracy"] for h in hist)


def traffic_to_reach(hist, target: float):
    for h in hist:
        if h["accuracy"] >= target:
            return h["traffic_mb"]
    return None
