"""Perf gate: diff fresh benchmark evidence against committed baselines.

``benchmarks/run.py`` writes structured JSON evidence (timings + analytic
roofline ledger + a ``_meta`` run-environment envelope) per bench. This
gate compares a freshly produced set against the baselines committed in
``experiments/results/`` and fails the build on regression:

* time fields (``us``, ``*_us``, ``*_s``) -- ratio check, ``fresh <=
  tolerance * baseline``. CPU wall clocks are noisy, so the default
  tolerance is generous (3x); the gate catches order-of-magnitude
  regressions (a fused kernel silently falling back to a per-leaf or
  per-step launch pattern), not 10% jitter.
* higher-is-better ratios (``*_speedup``, ``*_frac``) -- inverse ratio
  check, ``fresh >= baseline / tolerance``: the measured wall-clock
  overlap win (``wall_tta_speedup``, ``overlap_frac`` in ``async.json``)
  may jitter on shared CPU runners but must not collapse -- an
  overlapped dispatch silently degenerating to the blocking loop is a
  regression even though no raw time field got 3x slower.
* analytic fields (``flops``, ``*bytes*``, ``roofline_us``) and counters
  (``traces``, ``mediators``) -- EXACT. These are deterministic functions
  of the kernel's launch geometry; any drift means the kernel's cost
  model or launch pattern changed and the baseline must be consciously
  regenerated, never silently absorbed.
* identity strings (``shape``, ``mesh``, ``bound``) -- exact; a changed
  shape makes the timing comparison meaningless.
* booleans -- must not flip ``true -> false`` (e.g.
  ``online_bytes_equal_raw``, ``fixed_device_footprint``).
* baseline keys missing from the fresh evidence -- hard fail (a bench
  that silently stopped emitting a row is not a pass).

Before any of that, the ``_meta`` envelopes must agree on ``backend`` and
``interpret``: interpret-mode wall times are 100-1000x Mosaic, so diffing
a CPU/interpret run against a TPU baseline (or vice versa) is refused
outright (exit 2) rather than reported as pass/fail.

  PYTHONPATH=src python -m benchmarks.run --only kernels,agg --results-dir /tmp/perf
  PYTHONPATH=src python -m benchmarks.gate --fresh /tmp/perf --files kernels,agg

Exit codes: 0 pass, 1 regression, 2 refused/invalid comparison.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "results")
DEFAULT_FILES = "kernels,agg"
DEFAULT_TOLERANCE = 3.0

# exact-match numeric fields beyond the *bytes* pattern: analytic cost
# model outputs and determinism counters
EXACT_KEYS = {"flops", "roofline_us", "traces", "mediators", "device_count"}
# exact-match identity strings
EXACT_STR_KEYS = {"shape", "mesh", "bound"}
# derived from measured time (already ratio-gated) or environment-noisy
SKIP_KEYS = {"achieved_frac", "max_abs_diff", "federation_gen_s", "warm_s"}


def _is_time_key(key: str) -> bool:
    return (key == "us" or key.endswith("_us") or key.endswith("_s")
            or key.startswith("us_per"))


def _is_ratio_key(key: str) -> bool:
    """Higher-is-better measured ratios: gated from below."""
    return key.endswith("_speedup") or key.endswith("_frac")


def _exactly(a, b) -> bool:
    return bool(a == b) or (isinstance(a, float) and isinstance(b, float)
                            and math.isclose(a, b, rel_tol=1e-9))


def compare(fresh: dict, baseline: dict, *, tolerance: float = DEFAULT_TOLERANCE,
            path: str = "") -> list[str]:
    """All regressions of ``fresh`` vs ``baseline`` (empty list = pass)."""
    errs = []
    for key, bv in baseline.items():
        if key == "_meta" or key in SKIP_KEYS:
            continue
        p = f"{path}.{key}" if path else key
        if key not in fresh:
            errs.append(f"{p}: present in baseline but missing from fresh "
                        "evidence (bench stopped emitting it?)")
            continue
        fv = fresh[key]
        if isinstance(bv, dict):
            if not isinstance(fv, dict):
                errs.append(f"{p}: baseline is a dict, fresh is "
                            f"{type(fv).__name__}")
            else:
                errs.extend(compare(fv, bv, tolerance=tolerance, path=p))
        elif isinstance(bv, bool):
            if bv and not fv:
                errs.append(f"{p}: invariant flipped true -> false")
        elif isinstance(bv, str):
            if key in EXACT_STR_KEYS and fv != bv:
                errs.append(f"{p}: identity changed {bv!r} -> {fv!r} "
                            "(regenerate the baseline deliberately)")
        elif isinstance(bv, (int, float)):
            if not isinstance(fv, (int, float)) or isinstance(fv, bool):
                errs.append(f"{p}: baseline numeric, fresh "
                            f"{type(fv).__name__}")
            elif key in EXACT_KEYS or "bytes" in key:
                if not _exactly(float(fv), float(bv)):
                    errs.append(f"{p}: analytic/exact field changed "
                                f"{bv} -> {fv} (cost model or launch "
                                "geometry drift)")
            elif _is_time_key(key):
                if bv > 0 and fv > bv * tolerance:
                    errs.append(f"{p}: time regression {bv:.1f} -> {fv:.1f} "
                                f"({fv / bv:.2f}x > {tolerance:.2f}x)")
            elif _is_ratio_key(key):
                if bv > 0 and fv < bv / tolerance:
                    errs.append(f"{p}: measured ratio collapsed "
                                f"{bv:.2f} -> {fv:.2f} (below baseline / "
                                f"{tolerance:.2f} -- the win regressed, "
                                "not just jitter)")
    return errs


def check_meta(fresh: dict, baseline: dict) -> list[str]:
    """Refusals: comparisons that would be meaningless, not regressions."""
    fm, bm = fresh.get("_meta"), baseline.get("_meta")
    if not isinstance(fm, dict) or not isinstance(bm, dict):
        return ["missing _meta envelope (regenerate both sides with "
                "benchmarks.run)"]
    errs = []
    for key in ("backend", "interpret"):
        if fm.get(key) != bm.get(key):
            errs.append(f"_meta.{key}: baseline={bm.get(key)!r} vs "
                        f"fresh={fm.get(key)!r} -- refusing to diff "
                        "interpret-mode numbers against Mosaic (or across "
                        "backends); regenerate the baseline on this "
                        "backend instead")
    return errs


def gate_file(fresh_path: str, baseline_path: str, *,
              tolerance: float = DEFAULT_TOLERANCE
              ) -> tuple[list[str], list[str]]:
    """Returns (refusals, regressions) for one evidence file pair."""
    if not os.path.exists(baseline_path):
        return ([f"baseline {baseline_path} not found (commit one with "
                 "benchmarks.run)"], [])
    if not os.path.exists(fresh_path):
        return ([f"fresh evidence {fresh_path} not found (did the bench "
                 "run?)"], [])
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    refusals = check_meta(fresh, baseline)
    if refusals:
        return (refusals, [])
    return ([], compare(fresh, baseline, tolerance=tolerance))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True,
                    help="directory holding the freshly generated JSONs")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline directory "
                         "(default: experiments/results)")
    ap.add_argument("--files", default=DEFAULT_FILES,
                    help=f"comma-separated evidence names "
                         f"(default: {DEFAULT_FILES})")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="max fresh/baseline wall-time ratio "
                         f"(default: {DEFAULT_TOLERANCE})")
    args = ap.parse_args(argv)

    any_refused, any_regressed = False, False
    for name in args.files.split(","):
        name = name.strip()
        refusals, regressions = gate_file(
            os.path.join(args.fresh, f"{name}.json"),
            os.path.join(args.baseline, f"{name}.json"),
            tolerance=args.tolerance)
        for r in refusals:
            print(f"REFUSED {name}: {r}")
            any_refused = True
        for r in regressions:
            print(f"FAIL {name}: {r}")
            any_regressed = True
        if not refusals and not regressions:
            print(f"OK {name}")
    if any_refused:
        return 2
    if any_regressed:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
