"""Two-process ``jax.distributed`` smoke: overlapped waves across hosts.

CI's ``tier1-async-overlap`` job runs:

    PYTHONPATH=src python -m benchmarks.distributed_smoke

which launches itself twice as coordinator + worker (``--role child``),
and on each process:

* joins the coordination service (``launch.mesh.init_distributed``),
  builds a process-local mesh, and constructs the same tiny federation
  and engine from the same seeds;
* runs 2 overlapped async rounds with a ``ProcessWaveDispatcher``: each
  wave executes on exactly one process and its contribution crosses the
  process boundary host-side through the coordination-service KV store
  (cross-process XLA collectives are not implemented on the CPU
  backend, so this is the only portable exchange);
* asserts the acceptance contract -- the committed server params are
  BITWISE identical across processes (exchanged via the KV store), both
  processes fold every wave (commit logs match), and the WAN ledger is
  process-count-invariant: every per-key total equals the single-process
  run of the identical configuration, byte for byte.

Exit status is nonzero on any violation or on a hung child (hard
timeout), so the CI leg cannot wedge on a lost barrier.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys

CHILD_TIMEOUT_S = 420


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_rounds(dispatcher=None, telemetry=None):
    """The workload both the child processes and the single-process
    reference run: 2 overlapped async rounds on the tiny federation."""
    import dataclasses

    from repro.core import LocalSpec
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.staleness import StragglerSpec
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh, process_local_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600,
                    test_samples=160, sizes="instagram",
                    global_dist="letterfreq", local="random", seed=0,
                    name="dist-smoke")
    model = emnist_cnn(fed.num_classes, image_size=16)
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=3, donate_params=False,
                               row_exec="map")
    mesh = process_local_mesh() if dispatcher is not None \
        else make_mediator_mesh(1)
    eng = FLRoundEngine(model, adam(1e-3), fed, cfg, mesh=mesh,
                        telemetry=telemetry)
    aspec = AsyncSpec(staleness_bound=0, wave_size=1,
                      straggler=StragglerSpec(model="lognormal", seed=3),
                      dispatch="overlapped")
    a = AsyncRoundEngine(eng, aspec, dispatcher=dispatcher)
    for _ in range(2):
        a.run_round()
    a.flush()
    return a


def _child(args) -> int:
    import jax
    import numpy as np

    from repro.launch.mesh import ProcessWaveDispatcher, init_distributed

    assert init_distributed(args.coordinator, args.num_processes,
                            args.process_id), "distributed init failed"
    pid, nproc = jax.process_index(), jax.process_count()
    print(f"[child {pid}] joined: {nproc} processes", flush=True)
    disp = ProcessWaveDispatcher(timeout_ms=120_000)
    a = _run_rounds(dispatcher=disp)

    leaves = [np.asarray(x) for x in jax.tree.leaves(a.params)]
    failures = []

    def check(cond, msg):
        print(f"[child {pid}] [{'ok' if cond else 'FAIL'}] {msg}",
              flush=True)
        if not cond:
            failures.append(msg)

    check(a.num_commits == 2, f"one S=0 commit per round, "
                              f"got {a.num_commits}")
    check(disp.num_published > 0 and disp.num_received > 0,
          f"waves crossed the process boundary "
          f"(pub={disp.num_published}, recv={disp.num_received})")

    # params cross-check: everyone publishes, everyone diffs rank 0's
    disp.publish(f"smoke-params-{pid}", leaves)
    disp.barrier("params-ready")
    ref = disp.receive("smoke-params-0")
    same = all(np.array_equal(x, y) for x, y in zip(leaves, ref))
    check(same, "server params bitwise identical across processes")

    # WAN ledger process-count invariance: compare against rank 0's
    # ledger AND (on rank 0) the single-process reference run
    totals = a.comm.ledger_totals()
    keys = sorted(totals)
    vec = np.asarray([totals[k] for k in keys], np.float64)
    disp.publish(f"smoke-ledger-{pid}", [vec])
    disp.barrier("ledger-ready")
    ref_vec = disp.receive("smoke-ledger-0")[0]
    check(np.array_equal(vec, ref_vec),
          "WAN ledger identical across processes")
    if pid == 0:
        solo = _run_rounds(dispatcher=None)
        solo_totals = solo.comm.ledger_totals()
        check(sorted(solo_totals) == keys and all(
            solo_totals[k] == totals[k] for k in keys),
            "WAN ledger equals the single-process run (process-count "
            "invariant)")
        solo_leaves = [np.asarray(x) for x in jax.tree.leaves(solo.params)]
        check(all(np.array_equal(x, y)
                  for x, y in zip(leaves, solo_leaves)),
              "params bitwise equal to the single-process run")
    disp.barrier("done")
    if failures:
        print(f"[child {pid}] {len(failures)} failure(s)", flush=True)
        return 1
    print(f"[child {pid}] all checks passed", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=("parent", "child"), default="parent")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()
    if args.role == "child":
        return _child(args)

    coord = f"127.0.0.1:{_free_port()}"
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    # one CPU device per process: the point is cross-PROCESS dispatch
    env.pop("XLA_FLAGS", None)
    procs = []
    for pid in range(args.num_processes):
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "benchmarks.distributed_smoke",
             "--role", "child", "--coordinator", coord,
             "--num-processes", str(args.num_processes),
             "--process-id", str(pid)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True))
    rc = 0
    for pid, p in enumerate(procs):
        try:
            out, _ = p.communicate(timeout=CHILD_TIMEOUT_S)
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
            print(f"--- child {pid} TIMED OUT after {CHILD_TIMEOUT_S}s ---")
            rc = 1
        sys.stdout.write(out)
        if p.returncode != 0:
            rc = 1
    print("distributed smoke:", "PASS" if rc == 0 else "FAIL")
    return rc


if __name__ == "__main__":
    sys.exit(main())
