"""End-to-end telemetry smoke: traced rounds -> validated artifacts.

CI's ``telemetry-smoke`` job (and anyone debugging the obs stack) runs:

    PYTHONPATH=src python -m benchmarks.telemetry_smoke --out DIR

which executes one traced synchronous Astraea round and a bounded-
staleness (S=1) async round on a tiny federation, then asserts the
full acceptance contract of the obs subsystem:

* every event line in ``events.jsonl`` parses, carries the schema
  version, and nests correctly (``obs.validate_events``);
* ``trace.json`` is Chrome-trace/Perfetto loadable (``traceEvents``);
* the round executable compiled exactly once (``num_round_traces == 1``)
  despite tracing being on;
* the Prometheus exposition served over a live ``/metrics`` scrape
  reports ``astraea_wan_bytes_total`` exactly equal to the engine's
  ``CommMeter.total_bytes`` ledger.

Exit status is nonzero on any violation; artifacts stay in ``--out``
for upload.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import urllib.request


def _check(cond: bool, msg: str, failures: list) -> None:
    status = "ok" if cond else "FAIL"
    print(f"[{status}] {msg}", flush=True)
    if not cond:
        failures.append(msg)


def _traced_run(out_dir: str, tag: str, *, async_s: int | None,
                failures: list) -> None:
    import jax
    from repro.core import LocalSpec
    from repro.core.astraea import AstraeaTrainer
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.launch.metrics_endpoint import MetricsServer
    from repro.models.cnn import emnist_cnn
    from repro.obs import Telemetry, load_jsonl, validate_events
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name=f"smoke-{tag}")
    model = emnist_cnn(fed.num_classes, image_size=16)

    trace_dir = os.path.join(out_dir, tag)
    tel = Telemetry(trace_dir)
    kw = {}
    if async_s is not None:
        from repro.core.async_engine import AsyncSpec
        from repro.core.staleness import StragglerSpec
        kw["async_spec"] = AsyncSpec(
            staleness_bound=async_s, wave_size=1,
            straggler=StragglerSpec(model="fixed", straggler_frac=0.25,
                                    slowdown=4.0, seed=0))
    tr = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=6, gamma=3,
                        local=LocalSpec(10, 1), alpha=None, seed=0,
                        mesh=make_mediator_mesh(jax.device_count()),
                        telemetry=tel, **kw)
    tr.run_round()
    tr.run_round()
    if async_s is not None:
        tr.runner.flush()
    paths = tel.flush()

    # ---- span stream: parses, schema-tagged, properly nested ----
    try:
        events = load_jsonl(paths["events_jsonl"])
        validate_events(events)
        _check(True, f"{tag}: {len(events)} events validate "
                     f"({paths['events_jsonl']})", failures)
    except Exception as e:                                  # noqa: BLE001
        _check(False, f"{tag}: events.jsonl invalid: {e}", failures)
        events = []
    names = {e["name"] for e in events}
    want = {"round", "pack", "store_stream"}
    _check(want <= names, f"{tag}: span taxonomy present {sorted(names)}",
           failures)
    if async_s is not None:
        _check({"wave", "commit"} <= names,
               f"{tag}: async wave/commit spans present", failures)

    # ---- Chrome trace: Perfetto-loadable envelope ----
    with open(paths["trace_json"]) as f:
        chrome = json.load(f)
    _check(isinstance(chrome.get("traceEvents"), list)
           and len(chrome["traceEvents"]) == len(events),
           f"{tag}: trace.json has {len(chrome.get('traceEvents', []))} "
           f"traceEvents", failures)

    # ---- the zero-retrace contract under tracing ----
    _check(tr.engine.num_round_traces == 1,
           f"{tag}: num_round_traces == 1 with telemetry on "
           f"(got {tr.engine.num_round_traces})", failures)

    # ---- live /metrics scrape == the WAN ledger, byte for byte ----
    with MetricsServer(tel.metrics) as srv:
        body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
    wan = None
    for line in body.splitlines():
        if line.startswith("astraea_wan_bytes_total "):
            wan = float(line.split()[1])
    _check(wan is not None and wan == float(tr.comm.total_bytes),
           f"{tag}: scraped astraea_wan_bytes_total ({wan}) == "
           f"CommMeter.total_bytes ({tr.comm.total_bytes})", failures)
    with open(os.path.join(trace_dir, "scrape.prom"), "w") as f:
        f.write(body)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", required=True,
                    help="artifact directory (events.jsonl, trace.json, "
                         "metrics.prom, scrape.prom per arm)")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    failures: list = []
    _traced_run(args.out, "sync", async_s=None, failures=failures)
    _traced_run(args.out, "async_s1", async_s=1, failures=failures)
    if failures:
        print(f"telemetry smoke: {len(failures)} failure(s)", flush=True)
        return 1
    print("telemetry smoke: all checks passed", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
