"""Roofline table builder: reads experiments/dryrun/*.json and emits the
EXPERIMENTS.md §Roofline table + CSV rows (one per arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
DRYRUN_OPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                              "dryrun_opt")


def load_records(mesh: str | None = None, tag: str = "", base_dir: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(base_dir or DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def csv_rows(mesh: str = "single16x16", *, optimized: bool = False):
    """name,us_per_call,derived -- us_per_call = roofline step-time bound."""
    rows = []
    prefix = "roofline_opt" if optimized else "roofline"
    for r in load_records(mesh, base_dir=DRYRUN_OPT_DIR if optimized else None):
        name = f"{prefix}/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "ok":
            rl = r["roofline"]
            step_us = max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6
            derived = (f"dom={rl['dominant']};useful={rl['useful_ratio']:.2f};"
                       f"peakGB={r['memory']['peak_estimate_gb']}")
        elif r["status"] == "skipped":
            step_us, derived = 0.0, "skipped=" + r["skip_reason"][:40].replace(",", ";")
        else:
            step_us, derived = -1.0, "FAILED"
        rows.append((name, step_us, derived))
    return rows


def markdown_table(mesh: str = "single16x16") -> str:
    lines = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | status | compute (ms) | memory (ms) | collective (ms) | dominant | HLO GFLOPs/dev | coll MB/dev | 6ND/HLO | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        if r["status"] == "ok":
            rl = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok "
                f"| {rl['compute_s']*1e3:.2f} | {rl['memory_s']*1e3:.2f} "
                f"| {rl['collective_s']*1e3:.2f} | **{rl['dominant']}** "
                f"| {rl['flops']/1e9:.1f} | {rl['collective_bytes']/2**20:.1f} "
                f"| {rl['useful_ratio']:.2f} | {r['memory']['peak_estimate_gb']:.2f} |")
        else:
            why = r.get("skip_reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                         f"| | | | | | | | | {why} |"[:220])
    return "\n".join(lines)


if __name__ == "__main__":
    for mesh in ("single16x16", "pod2x16x16"):
        print(markdown_table(mesh))
        print()
