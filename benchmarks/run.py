"""Benchmark harness: one function per paper table/figure + roofline/kernels.

Prints ``name,us_per_call,derived`` CSV per the repo convention. FL
benchmarks report ``us_per_call`` as wall-time per synchronization round
and ``derived`` as the accuracy/KLD/traffic result the paper's artifact
claims; roofline rows derive from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.run                # default scale
  PYTHONPATH=src python -m benchmarks.run --only motivation,kernels
  PYTHONPATH=src python -m benchmarks.run --full         # paper-closer scale
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import numpy as np

from benchmarks import fl_experiments as E
from benchmarks import roofline as R

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "results")
TRACE_DIR = None     # --trace-dir: per-bench obs.Telemetry artifact root


def _telemetry(tag):
    """A Telemetry handle writing under ``TRACE_DIR/<tag>``, or None.

    None (the default) keeps every engine on the zero-cost no-op stubs,
    so benchmark wall times are unchanged unless tracing was requested.
    """
    if TRACE_DIR is None:
        return None
    from repro.obs import Telemetry
    return Telemetry(os.path.join(TRACE_DIR, tag))


def _flush_telemetry(tel):
    if tel is not None:
        paths = tel.flush()
        _emit(f"trace/{os.path.basename(os.path.dirname(paths['events_jsonl']))}",
              0.0, f"events={paths['events_jsonl']}")


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _meta(**overrides):
    """Run-environment envelope embedded in every result JSON (``_meta``).

    The perf gate (benchmarks/gate.py) refuses to diff numbers produced
    under a different backend or interpret setting -- interpret-mode wall
    times are 100-1000x Mosaic and would otherwise read as regressions.
    """
    import jax
    meta = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }
    meta.update(overrides)
    return meta


def _save(name, obj, meta=None):
    obj = dict(obj)
    obj["_meta"] = meta if meta is not None else _meta()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=2, default=float)


# ----------------------------------------------------------------------
# Fig. 1(a): imbalance types degrade FedAvg (TABLE I datasets)
# ----------------------------------------------------------------------

def bench_motivation(scale: E.Scale):
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    results = {}
    settings = {
        "BAL1": dict(sizes="even", global_dist="balanced", local="matched"),
        "BAL2": dict(sizes="even", global_dist="balanced", local="random"),
        "INS": dict(sizes="instagram", global_dist="balanced", local="random"),
        "LTRF1": dict(sizes="instagram", global_dist="letterfreq", local="random"),
        "LTRF2": dict(sizes="instagram", global_dist="letterfreq", local="random"),
    }
    for name, kw in settings.items():
        mult = 2.0 if name == "LTRF2" else 1.0
        fed = E.make_fed(spec, scale, name=name, total_mult=mult, **kw)
        t0 = time.time()
        _, hist = E.run_fedavg(model, fed, scale)
        dt = (time.time() - t0) / scale.rounds * 1e6
        acc = E.best_acc(hist)
        results[name] = acc
        _emit(f"motivation/{name}", dt, f"top1={acc:.4f}")
    delta = results["INS"] - results["LTRF1"]
    _emit("motivation/global_imbalance_loss", 0.0,
          f"acc_drop={delta:.4f} (paper: 0.0792)")
    _save("motivation", results)


# ----------------------------------------------------------------------
# Fig. 4/5: Astraea vs FedAvg on imbalanced EMNIST-like and CINIC-like
# ----------------------------------------------------------------------

def bench_accuracy(scale: E.Scale):
    for kind, specf in (("emnist", E.emnist_spec), ("cinic", E.cinic_spec)):
        spec = specf(scale)
        model = E.model_for(spec, scale, kind)
        gd = "letterfreq" if kind == "emnist" else "normal"
        fed = E.make_fed(spec, scale, global_dist=gd, name=f"imb-{kind}")
        t0 = time.time()
        _, fh = E.run_fedavg(model, fed, scale)
        fed_t = (time.time() - t0) / scale.rounds * 1e6
        t0 = time.time()
        _, ah = E.run_astraea(model, fed, scale, alpha=0.67, mediator_epochs=1)
        ast_t = (time.time() - t0) / scale.rounds * 1e6
        _, aug_h = E.run_astraea(model, fed, scale, alpha=0.67, gamma=1)
        fa, aa, ga = E.best_acc(fh), E.best_acc(ah), E.best_acc(aug_h)
        _emit(f"accuracy/{kind}/fedavg", fed_t, f"top1={fa:.4f}")
        _emit(f"accuracy/{kind}/astraea", ast_t, f"top1={aa:.4f}")
        _emit(f"accuracy/{kind}/aug_only", 0.0, f"top1={ga:.4f}")
        ra = None
        if kind == "emnist":
            # ablation partner: cost-sensitive loss reweighting (beyond-paper
            # baseline from classical imbalanced learning; see core.reweighting)
            from repro.core.reweighting import ReweightedFedAvgTrainer
            from repro.core import LocalSpec
            from repro.optim import adam
            tr = ReweightedFedAvgTrainer(model, adam(1e-3), fed,
                                         clients_per_round=scale.c,
                                         local=LocalSpec(scale.batch,
                                                         scale.local_epochs),
                                         seed=0)
            rh = tr.fit(scale.rounds, eval_every=scale.eval_every)
            ra = E.best_acc(rh)
            _emit(f"accuracy/{kind}/fedavg_reweighted", 0.0, f"top1={ra:.4f}")
        _emit(f"accuracy/{kind}/improvement", 0.0,
              f"delta={aa-fa:+.4f} (paper: {'+0.0559' if kind=='emnist' else '+0.0589'})")
        _save(f"accuracy_{kind}", {"fedavg": fa, "astraea": aa, "aug_only": ga,
                                   "fedavg_reweighted": ra})


# ----------------------------------------------------------------------
# Fig. 4(a)/Fig. 9: alpha sweep incl. the alpha=2 failure + storage cost
# ----------------------------------------------------------------------

def bench_alpha_sweep(scale: E.Scale):
    # materialized mode: the sweep reproduces the paper's Fig. 9 *realized*
    # storage cost; the online pipeline (which avoids it) is benchmarked in
    # bench_augmentation
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    fed = E.make_fed(spec, scale, name="alpha")
    out = {}
    for alpha in (None, 0.33, 0.67, 1.0, 2.0):
        t0 = time.time()
        tr, hist = E.run_astraea(model, fed, scale, alpha=alpha, gamma=1,
                                 mediator_epochs=1, aug_mode="materialized")
        dt = (time.time() - t0) / scale.rounds * 1e6
        acc = E.best_acc(hist)
        tag = "none" if alpha is None else f"{alpha:.2f}"
        out[tag] = {"acc": acc, "extra_storage": tr.extra_storage_frac}
        _emit(f"alpha_sweep/{tag}", dt,
              f"top1={acc:.4f};extra_storage={tr.extra_storage_frac:.2f}")
    _save("alpha_sweep", out)


# ----------------------------------------------------------------------
# Fig. 7: KLD distribution of mediators vs FedAvg clients
# ----------------------------------------------------------------------

def bench_kld(scale: E.Scale):
    import jax
    import jax.numpy as jnp
    from repro.core import distribution as dist, scheduling, augmentation
    spec = E.emnist_spec(scale)
    fed = E.make_fed(spec, scale, name="kld")
    counts = fed.client_counts()
    fedavg_kld = float(np.mean(np.asarray(dist.kld_to_uniform(jnp.asarray(counts)))))
    _emit("kld/fedavg_clients", 0.0, f"kld_mean={fedavg_kld:.3f} (paper: 0.550)")

    new_x, new_y, plan, _ = augmentation.rebalance_federation(
        jax.random.PRNGKey(0), fed.client_images, fed.client_labels,
        fed.num_classes, alpha=0.83)
    aug_counts = np.stack([np.bincount(y, minlength=fed.num_classes) for y in new_y])
    aug_kld = float(np.mean(np.asarray(dist.kld_to_uniform(
        jnp.asarray(aug_counts * 1.0)))))
    _emit("kld/aug_clients", 0.0, f"kld_mean={aug_kld:.3f} (paper: 0.498)")

    out = {"fedavg": fedavg_kld, "aug": aug_kld}
    for c, gamma in [(scale.c, scale.gamma), (scale.c, scale.gamma * 2),
                     (scale.num_clients, scale.gamma)]:
        rng = np.random.default_rng(0)
        sel = rng.choice(len(aug_counts), size=min(c, len(aug_counts)), replace=False)
        t0 = time.time()
        meds = scheduling.reschedule(aug_counts[sel].astype(float), gamma)
        dt = (time.time() - t0) * 1e6
        stats = scheduling.schedule_stats(meds)
        out[f"c{c}_g{gamma}"] = stats["kld_mean"]
        _emit(f"kld/mediators_c{c}_g{gamma}", dt,
              f"kld_mean={stats['kld_mean']:.3f} (paper: 0.125; target <0.2)")
    _save("kld", out)


# ----------------------------------------------------------------------
# Fig. 6: c vs gamma grid
# ----------------------------------------------------------------------

def bench_c_gamma(scale: E.Scale):
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    fed = E.make_fed(spec, scale, name="cg")
    out = {}
    for c in (scale.c, min(scale.c * 2, scale.num_clients)):
        for gamma in (scale.gamma, scale.gamma * 2):
            t0 = time.time()
            _, hist = E.run_astraea(model, fed, scale, c=c, gamma=gamma)
            dt = (time.time() - t0) / scale.rounds * 1e6
            acc = E.best_acc(hist)
            out[f"c{c}_g{gamma}"] = acc
            _emit(f"c_gamma/c{c}_g{gamma}", dt, f"top1={acc:.4f}")
    _save("c_gamma", out)


# ----------------------------------------------------------------------
# Fig. 8: local epochs E vs mediator epochs E_m
# ----------------------------------------------------------------------

def bench_epochs(scale: E.Scale):
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    fed = E.make_fed(spec, scale, name="epochs")
    out = {}
    for e in (1, scale.local_epochs * 2):
        for em in (1, 2):
            t0 = time.time()
            _, hist = E.run_astraea(model, fed, scale, local_epochs=e,
                                    mediator_epochs=em)
            dt = (time.time() - t0) / scale.rounds * 1e6
            acc = E.best_acc(hist)
            out[f"E{e}_Em{em}"] = acc
            _emit(f"epochs/E{e}_Em{em}", dt, f"top1={acc:.4f}")
    _save("epochs", out)


# ----------------------------------------------------------------------
# TABLE III: communication cost to a target accuracy
# ----------------------------------------------------------------------

def bench_communication(scale: E.Scale):
    """Paper Table III. The paper's 0.18x bytes ratio lives in the regime
    where FedAvg needs hundreds of cheap rounds to crawl to the target
    (500 clients, 47 classes); at CPU scale FedAvg converges in ~25
    rounds, so the binding cost is SYNC ROUNDS, not bytes. We report both:
    rounds-to-target (the mechanism: Astraea converges ~3x faster per
    round) and the traffic ledger (which flips at this scale -- an honest
    scale-dependence finding, see EXPERIMENTS.md §Claims)."""
    import dataclasses
    lscale = dataclasses.replace(scale, rounds=24, eval_every=2)
    spec = E.emnist_spec(lscale)
    model = E.model_for(spec, lscale)
    fed = E.make_fed(spec, lscale, name="comm")
    _, fh = E.run_fedavg(model, fed, dataclasses.replace(lscale, c=6),
                         local_epochs=4)
    fed_best = E.best_acc(fh)
    target = 0.95 * fed_best
    base_mb = E.traffic_to_reach(fh, target)
    base_rounds = next((h["round"] for h in fh if h["accuracy"] >= target), None)
    _emit("communication/fedavg_baseline", 0.0,
          f"target={target:.3f};mb={base_mb:.1f};rounds={base_rounds}")
    out = {"target": target, "fedavg_mb": base_mb, "fedavg_rounds": base_rounds}
    for em in (1, 2, 3):
        _, hist = E.run_astraea(model, fed,
                                dataclasses.replace(lscale, c=18, gamma=6),
                                mediator_epochs=em, local_epochs=1)
        mb = E.traffic_to_reach(hist, target)
        rnd = next((h["round"] for h in hist if h["accuracy"] >= target), None)
        mb_ratio = f"{mb/base_mb:.2f}x" if (mb and base_mb) else "n/a"
        rnd_ratio = f"{rnd/base_rounds:.2f}x" if (rnd and base_rounds) else "n/a"
        out[f"med{em}_mb"] = mb
        out[f"med{em}_rounds"] = rnd
        _emit(f"communication/med{em}", 0.0,
              f"mb={f'{mb:.1f}' if mb else 'not-reached'};mb_ratio={mb_ratio};"
              f"rounds={rnd};round_ratio={rnd_ratio} "
              f"(paper Med2 bytes: 0.18x; mechanism = fewer rounds)")
    _save("communication", out)


# ----------------------------------------------------------------------
# Round-engine benchmark: per-round host repacking (old trainers) vs the
# packed-once device-resident engine, at M mediators
# ----------------------------------------------------------------------

def bench_engine(scale: E.Scale, stores: tuple = ("replicated",)):
    """us_per_call = wall time per synchronization round. ``legacy`` is the
    pre-engine path (numpy (M, gamma, pad, ...) repack on the host every
    round); ``engine`` gathers from packed-once device buffers inside the
    jitted round. ``packs`` counts host packing events: 1 per schedule for
    the engine, 1 per round for the legacy path. The ``--store`` axis
    benchmarks the ClientStore placement policies (replicated / sharded /
    host); ``store_bytes`` is per-device client-store residency -- on this
    1-device container sharded matches replicated (n=1); the per-device
    reduction shows up on real multi-device meshes."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.core import LocalSpec, scheduling
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.fl import weighted_average
    from repro.core.mediator import make_mediator_update
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    gamma, batch, reps = 2, 12, 3
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    model = emnist_cnn(8, image_size=16)
    local = LocalSpec(batch, 1)
    out = {}
    for m_target in (4, 16, 64):
        k = m_target * gamma
        fed = partition(spec, num_clients=k, total_samples=k * 2 * batch,
                        test_samples=64, sizes="even", global_dist="balanced",
                        local="random", seed=0, name=f"eng{m_target}")
        store_rows = {}
        for store in stores:
            tel = _telemetry(f"engine_M{m_target}_{store}")
            eng = FLRoundEngine(
                model, adam(1e-3), fed,
                EngineConfig.astraea(clients_per_round=k, gamma=gamma,
                                     local=local, store=store,
                                     pad_mediators_to=m_target, seed=0),
                telemetry=tel)
            eng.run_round()                  # compile + schedule pack
            jax.block_until_ready(eng.params)
            t0 = time.time()
            for _ in range(reps):
                eng.run_round()
            jax.block_until_ready(eng.params)
            us = (time.time() - t0) / reps * 1e6
            _flush_telemetry(tel)
            store_rows[store] = {
                "us": us, "store_bytes": eng.store.per_device_bytes(),
                "traces": eng.num_round_traces}
            if store == "replicated":
                new_us = us
        if "replicated" not in stores:
            new_us = next(iter(store_rows.values()))["us"]

        # ---- legacy reference: numpy repack inside the round loop.
        # Intentionally mirrors tests/test_engine.py::_legacy_astraea_run,
        # which proves this exact round bit-identical to the engine; keep
        # the two in sync if the reference semantics ever change. ----
        sizes = [x.shape[0] for x in fed.client_images]
        pad = ((max(sizes) + batch - 1) // batch) * batch
        X, Y, MK = fed.padded(pad)
        rng = np.random.default_rng(0)
        sel = rng.choice(fed.num_clients, size=k, replace=False)
        meds = scheduling.reschedule(fed.client_counts()[sel], gamma)
        groups = [[int(sel[i]) for i in mm.clients] for mm in meds]
        m_count = len(groups)
        med_upd = make_mediator_update(model, adam(1e-3), local, 1)

        @jax.jit
        def round_fn(params, xs, ys, ms, keys):
            deltas = jax.vmap(med_upd, in_axes=(None, 0, 0, 0, 0))(
                params, xs, ys, ms, keys)
            delta = weighted_average(deltas, ms.sum(axis=(1, 2)))
            return jax.tree.map(lambda p, d: p + d, params, delta)

        def legacy_round(params, r):
            t_pack = time.time()
            xs = np.zeros((m_count, gamma, pad) + X.shape[2:], np.float32)
            ys = np.zeros((m_count, gamma, pad), np.int32)
            ms = np.zeros((m_count, gamma, pad), np.float32)
            for mi, clients in enumerate(groups):
                for ci, cid in enumerate(clients):
                    xs[mi, ci] = X[cid]
                    ys[mi, ci] = Y[cid]
                    ms[mi, ci] = MK[cid]
            pack_s = time.time() - t_pack
            keys = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(1), r), m_count)
            return round_fn(params, jnp.asarray(xs), jnp.asarray(ys),
                            jnp.asarray(ms), keys), pack_s

        params = model.init(jax.random.PRNGKey(0))
        params, _ = legacy_round(params, 0)  # compile
        jax.block_until_ready(params)
        t0, pack_total = time.time(), 0.0
        for r in range(reps):
            params, pack_s = legacy_round(params, r + 1)
            pack_total += pack_s
        jax.block_until_ready(params)
        old_us = (time.time() - t0) / reps * 1e6
        pack_us = pack_total / reps * 1e6

        _emit(f"engine/M{m_count}/legacy", old_us,
              f"pack_us={pack_us:.0f};packs_per_round=1")
        for store, row in store_rows.items():
            _emit(f"engine/M{m_count}/{store}", row["us"],
                  f"speedup={old_us / row['us']:.2f}x;"
                  f"store_bytes={row['store_bytes']};traces={row['traces']}")
        out[f"M{m_count}"] = {"legacy_us": old_us, "engine_us": new_us,
                              "pack_us": pack_us,
                              "engine_packs": eng.num_schedule_packs,
                              "engine_rounds": eng._round,
                              "stores": store_rows}
    _save("engine", out)


# ----------------------------------------------------------------------
# Online rebalancing: warp-kernel vs map_coordinates resampler, online vs
# materialized round throughput, and per-device client-store residency
# ----------------------------------------------------------------------

def bench_augmentation(scale: E.Scale):
    """The Alg. 2 execution-mode matrix (ISSUE 4). Three axes:

    * ``warp/*`` -- the augmentation primitive itself: the fused Pallas
      bilinear-warp kernel (one launch per batch; interpret mode on CPU,
      where it is expected to LOSE to XLA -- the win is the single-launch
      Mosaic path on TPU) vs the vectorized map_coordinates reference.
    * ``round/*`` -- wall time per synchronization round with augmentation
      off / online (in-round resample+warp) / materialized (pre-inflated
      federation): the online tax is paid in round compute, the
      materialized tax in storage + packed-batch size.
    * ``store_bytes/*`` -- per-device client-store residency: online must
      equal raw under every placement policy; materialized inflates it by
      ``extra_storage_frac`` (the paper's ~24%; larger at toy scale).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import LocalSpec, augmentation
    from repro.core.astraea import AstraeaTrainer
    from repro.kernels import ops, ref as kref
    from repro.optim import adam

    key = jax.random.PRNGKey(0)
    out = {}

    # ---- warp primitive: pallas kernel vs map_coordinates reference ----
    b, hw = 64, scale.image
    imgs = jax.random.normal(key, (b, hw, hw, 1), jnp.float32)
    mats, trans = augmentation.warp_params(jax.random.fold_in(key, 1), b)

    def timeit(fn, *args, n=5):
        jax.block_until_ready(fn(*args))
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    ref_fn = jax.jit(lambda i, m, t: kref.affine_warp(i, m, t))
    us_k = timeit(ops.affine_warp, imgs, mats, trans)
    us_r = timeit(ref_fn, imgs, mats, trans)
    out["warp"] = {"pallas_us": us_k, "map_coordinates_us": us_r,
                   "batch": b, "image": hw}
    _emit("augmentation/warp/pallas", us_k,
          f"map_coordinates_us={us_r:.1f};n={b}x{hw}x{hw} "
          f"(interpret mode on CPU; kernel targets TPU Mosaic)")

    # ---- execution modes: round time + per-device store residency ----
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    fed = E.make_fed(spec, scale, name="aug")
    reps = 3
    modes = {"none": dict(alpha=None),
             "online": dict(alpha=0.67, aug_mode="online"),
             "materialized": dict(alpha=0.67, aug_mode="materialized")}
    for mode, kw in modes.items():
        tr = AstraeaTrainer(model, adam(1e-3), fed,
                            clients_per_round=scale.c, gamma=scale.gamma,
                            local=LocalSpec(scale.batch, 1), seed=0, **kw)
        tr.run_round()                       # compile + schedule pack
        jax.block_until_ready(tr.params)
        t0 = time.time()
        for _ in range(reps):
            tr.run_round()
        jax.block_until_ready(tr.params)
        us = (time.time() - t0) / reps * 1e6
        row = {"us_per_round": us,
               "store_bytes": tr.engine.store.per_device_bytes(),
               "extra_storage_frac": tr.extra_storage_frac,
               "planned_extra_frac": tr.planned_extra_frac,
               "traces": tr.engine.num_round_traces}
        out[mode] = row
        _emit(f"augmentation/round/{mode}", us,
              f"store_bytes={row['store_bytes']};"
              f"extra_storage={row['extra_storage_frac']:.2f};"
              f"traces={row['traces']}")
    raw_b = out["none"]["store_bytes"]
    _emit("augmentation/store_bytes", 0.0,
          f"online_vs_raw={out['online']['store_bytes'] / raw_b:.2f}x;"
          f"materialized_vs_raw={out['materialized']['store_bytes'] / raw_b:.2f}x"
          " (online must be 1.00x)")
    out["online_bytes_equal_raw"] = bool(
        out["online"]["store_bytes"] == raw_b)
    _save("augmentation", out)


# ----------------------------------------------------------------------
# Eq. 6 aggregation on the 2-D mediator x model mesh: fused fedavg_agg
# kernel vs the replicated weighted-average path (ROADMAP "kernel
# aggregation at scale")
# ----------------------------------------------------------------------

_AGG_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.pop("ASTRAEA_MODEL_PARALLEL", None)
import json, time
import jax, jax.numpy as jnp
from repro.core.engine import eq6_aggregate
from repro.launch.mesh import make_fl_mesh, replicated_sharding

results = {}
for med, mod in ((4, 1), (2, 2)):
    mesh = make_fl_mesh(mediator=med, model=mod)
    rep = replicated_sharding(mesh)
    for m in (16, 64):
        key = jax.random.PRNGKey(0)
        tree = {f"w{i}": jax.device_put(
                    jax.random.normal(jax.random.fold_in(key, i),
                                      (m, 1 << 14), jnp.float32), rep)
                for i in range(4)}
        wts = jax.device_put(jnp.arange(1.0, m + 1.0), rep)
        base = jax.jit(lambda t, w: eq6_aggregate(t, w, mesh))
        kern = jax.jit(lambda t, w: eq6_aggregate(t, w, mesh,
                                                  use_kernel_agg=True))

        def timeit(fn, n=5):
            jax.block_until_ready(fn(tree, wts))
            t0 = time.time()
            for _ in range(n):
                jax.block_until_ready(fn(tree, wts))
            return (time.time() - t0) / n * 1e6

        a, b = base(tree, wts), kern(tree, wts)
        diff = max(float(jnp.max(jnp.abs(x - y)))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        results[f"{med}x{mod}/M{m}"] = {
            "mesh": f"{med}x{mod}", "mediators": m,
            "weighted_avg_us": timeit(base), "kernel_us": timeit(kern),
            "max_abs_diff": diff}
print("JSON:" + json.dumps(results))
"""


def bench_agg(scale: E.Scale):
    """``fedavg_agg_tree`` (fused Pallas kernel; interpret mode on this CPU
    container, Mosaic on TPU) vs the engine's default replicated
    weighted-average Eq. 6 path, on real 4-device ``4x1`` and ``2x2``
    meshes (subprocess: the forced device count must precede jax init).
    Closes the ROADMAP "kernel aggregation at scale" item: the comparison
    now runs on the multi-device meshes the engine actually deploys, not
    only single-device microbenchmarks."""
    import subprocess
    import sys
    import jax
    from repro.kernels import fedavg_agg as _fa
    from repro.roofline import kernel_roofline, achieved_fraction
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _AGG_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    line = next(l for l in proc.stdout.splitlines() if l.startswith("JSON:"))
    results = json.loads(line[len("JSON:"):])
    interp = jax.default_backend() != "tpu"
    n_total = 4 * (1 << 14)               # 4 leaves x 16384 f32, fused
    for name, row in results.items():
        cost = _fa.cost_estimate(row["mediators"], n_total, 4, 4)
        roof = kernel_roofline(cost.flops, cost.bytes_accessed)
        row.update({
            "flops": float(cost.flops),
            "bytes": float(cost.bytes_accessed),
            "roofline_us": roof["roofline_s"] * 1e6,
            "bound": roof["bound"],
            "achieved_frac": achieved_fraction(row["kernel_us"] * 1e-6,
                                               roof["roofline_s"]),
            "interpret": interp,
        })
        _emit(f"agg/{name}/kernel", row["kernel_us"],
              f"weighted_avg_us={row['weighted_avg_us']:.1f};"
              f"speedup={row['weighted_avg_us'] / row['kernel_us']:.2f}x;"
              f"max_abs_diff={row['max_abs_diff']:.2e};"
              f"roofline_us={row['roofline_us']:.3f};"
              f"achieved={row['achieved_frac']:.1e};interpret={interp}")
    _save("agg", results, meta=_meta(device_count=4))


# ----------------------------------------------------------------------
# Async aggregation: sync barrier vs bounded-staleness waves under a
# 4x straggler (simulated round time + rounds-to-accuracy)
# ----------------------------------------------------------------------

def bench_async(scale: E.Scale):
    """Bounded-staleness async rounds (core/async_engine.py) vs the
    synchronous barrier on the same simulated straggler fleet (one slot
    4x slow). ``us_per_call`` is host wall-time per round (the simulator
    executes every wave, so it is NOT the deployment win); the deployment
    numbers live in ``derived``: ``round_speedup`` is barrier time /
    async virtual time per round, and ``tta_speedup`` is the Table-III
    style metric -- simulated time for async to reach the sync run's
    final accuracy minus ACC_TOL (async rounds are cheaper, so it may run
    up to 2x as many). Acceptance bar: tta_speedup >= 1.5x at S=1 under
    the 4x straggler."""
    from repro.core import LocalSpec
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.staleness import StragglerSpec
    from repro.optim import adam

    ACC_TOL = 0.05
    rounds, eval_every = scale.rounds, 2
    spec = E.emnist_spec(scale)
    model = E.model_for(spec, scale)
    fed = E.make_fed(spec, scale, name="async")
    gamma = scale.gamma // 2 or 1
    cfg = EngineConfig.astraea(clients_per_round=scale.c, gamma=gamma,
                               local=LocalSpec(scale.batch, 1), seed=0)
    straggler = StragglerSpec(model="fixed", straggler_frac=0.25,
                              slowdown=4.0, seed=0)

    t0 = time.time()
    sync = FLRoundEngine(model, adam(1e-3), fed, cfg)
    sh = sync.fit(rounds, eval_every=eval_every)
    sync_us = (time.time() - t0) / rounds * 1e6
    target = sh[-1]["accuracy"] - ACC_TOL
    out = {"rounds": rounds, "straggler_slowdown": straggler.slowdown,
           "acc_tol": ACC_TOL, "target_accuracy": target,
           "sync": {"accuracy": sh[-1]["accuracy"],
                    "traffic_mb": sh[-1]["traffic_mb"]}}
    sync_sim_time = None        # the S=0 arm's barrier clock (same fleet)

    for s_bound in (0, 1, 2):
        tel = _telemetry(f"async_S{s_bound}")
        eng = FLRoundEngine(model, adam(1e-3), fed, cfg, telemetry=tel)
        a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=s_bound,
                                            wave_size=1,
                                            straggler=straggler))
        # S=0 is the bitwise-sync control (same rounds); bounded-staleness
        # runs get the same simulated-time budget expressed in their own
        # cheaper rounds (up to 2x as many)
        arounds = rounds if s_bound == 0 else 2 * rounds
        t0 = time.time()
        ah = a.fit(arounds, eval_every=eval_every)
        us = (time.time() - t0) / arounds * 1e6
        h = ah[-1]
        if s_bound == 0:
            # S=0 is bitwise-sync, so its accumulated barrier time IS the
            # synchronous run's simulated clock on this straggler fleet
            sync_sim_time = h["sync_sim_time"]
            out["sync"]["sim_time"] = sync_sim_time
            _emit("async/sync_baseline", sync_us,
                  f"sim_time={sync_sim_time:.1f};"
                  f"top1={sh[-1]['accuracy']:.4f};target={target:.4f}")
        hit = next((x for x in ah if x["accuracy"] >= target), None)
        tta = hit["sim_time"] if hit else None
        row = {"rounds": arounds, "accuracy": h["accuracy"],
               "round_speedup": h["sim_speedup"],
               "time_to_target": tta,
               "tta_speedup": sync_sim_time / tta if tta else None,
               "rounds_to_target": hit["round"] if hit else None,
               "sim_time": h["sim_time"],
               "staleness_mean": h["staleness_mean"],
               "staleness_max": h["staleness_max"],
               "commits": h["commits"], "traffic_mb": h["traffic_mb"],
               "traces": eng.num_round_traces}
        out[f"S{s_bound}"] = row
        _flush_telemetry(tel)
        tta_s = f"{row['tta_speedup']:.2f}x" if tta else "not-reached"
        _emit(f"async/S{s_bound}", us,
              f"round_speedup={row['round_speedup']:.2f}x;"
              f"tta_speedup={tta_s};top1={h['accuracy']:.4f};"
              f"stale_max={row['staleness_max']};traces={row['traces']} "
              f"(target: tta>=1.50x under 4x straggler)")
    s1 = out["S1"]
    out["meets_target"] = bool(s1["tta_speedup"] is not None
                               and s1["tta_speedup"] >= 1.5)

    # ---- measured WALL-CLOCK arms: blocking wave loop vs overlapped
    # dispatch. Both arms run the identical S=1 trajectory (row_exec=
    # "map" makes sliced and masked waves bitwise-equal), so any
    # wall-clock gap is pure dispatch efficiency: the blocking arm runs
    # the full padded-M program per wave AND hosts a block after each,
    # the overlapped arm runs sliced executables with no host sync until
    # the eval boundary. Round 0 (compilation of every wave width) is
    # excluded from the timed window on both arms; eval cost is excluded
    # by stopping the clock across evaluations.
    import dataclasses as _dc

    from repro.core.fl import evaluate as _evaluate

    cfg_map = _dc.replace(cfg, row_exec="map")

    def _wall_arm(tag, **akw):
        eng = FLRoundEngine(model, adam(1e-3), fed, cfg_map)
        a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=1, wave_size=1,
                                            straggler=straggler, **akw))
        a.run_round()               # compile window (all wave widths)
        a.synchronize()
        wall = 0.0
        wall_tta = rounds_tta = None
        acc = 0.0
        t = time.perf_counter()
        for i in range(1, arounds):
            a.run_round()
            if (i + 1) % eval_every == 0 or i == arounds - 1:
                a.synchronize()     # the pipeline's one host sync point
                wall += time.perf_counter() - t
                m = _evaluate(eng.model, eng.merged_params(),
                              fed.test_images, fed.test_labels)
                acc = m["accuracy"]
                if wall_tta is None and acc >= target:
                    wall_tta, rounds_tta = wall, i + 1
                t = time.perf_counter()
        a.flush()
        row = {"rounds_timed": arounds - 1, "accuracy": acc,
               "wall_train_s": wall, "wall_time_to_target_s": wall_tta,
               "rounds_to_target": rounds_tta,
               "overlap_frac": a.overlap_frac,
               "traces": eng.num_round_traces}
        tta_s = f"{wall_tta:.2f}s" if wall_tta else "not-reached"
        _emit(f"async/wall_{tag}", wall / (arounds - 1) * 1e6,
              f"wall_train_s={wall:.2f};wall_tta={tta_s};"
              f"overlap_frac={a.overlap_frac:.2f}")
        return row

    blocking = _wall_arm("blocking", dispatch="masked",
                         block_each_wave=True)
    overlapped = _wall_arm("overlapped", dispatch="overlapped")
    # identical trajectories -> identical rounds-to-target; guard anyway
    wall_speedup = None
    if blocking["wall_time_to_target_s"] and \
            overlapped["wall_time_to_target_s"]:
        wall_speedup = blocking["wall_time_to_target_s"] / \
            overlapped["wall_time_to_target_s"]
    out["wall_clock"] = {
        "blocking": blocking, "overlapped": overlapped,
        "wall_tta_speedup": wall_speedup,
        "wall_round_speedup": blocking["wall_train_s"] /
        max(overlapped["wall_train_s"], 1e-9),
        "overlap_frac": overlapped["overlap_frac"],
    }
    # acceptance: overlapped dispatch reaches target >= 1.3x faster in
    # measured wall time than the blocking wave loop (perf-gated)
    out["meets_wall_target"] = bool(wall_speedup is not None
                                    and wall_speedup >= 1.3)
    tta_sp = f"{wall_speedup:.2f}x" if wall_speedup else "not-reached"
    _emit("async/wall_speedup",
          out["wall_clock"]["wall_round_speedup"] * 1e6,
          f"wall_tta_speedup={tta_sp};"
          f"overlap_frac={overlapped['overlap_frac']:.2f} "
          f"(target: >=1.30x)")
    _save("async", out)


# ----------------------------------------------------------------------
# Million-client streaming ClientStore: bytes-moved and round-time vs K
# (spill tier + async prefetch), plus the 4-device placement-policy and
# ragged-vs-gather exchange comparison
# ----------------------------------------------------------------------

_STORE_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.pop("ASTRAEA_MODEL_PARALLEL", None)
import json
import jax
from repro.core import LocalSpec
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.data.synthetic import (SyntheticSpec, StreamingFederation,
                                  federation_counts)
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam

spec = SyntheticSpec(num_classes=8, image_size=16)
stream = StreamingFederation(spec, federation_counts(64, 8, seed=3),
                             batch_size=12, seed=3)
fed = stream.materialize()
model = emnist_cnn(8, image_size=16)
mesh = make_mediator_mesh(4)
ROUNDS = 3
results, params = {}, {}
for store, exchange in (("replicated", "ragged"), ("sharded", "ragged"),
                        ("sharded", "gather"), ("host", "ragged"),
                        ("spilled", "ragged")):
    eng = FLRoundEngine(
        model, adam(1e-3), fed,
        EngineConfig.astraea(clients_per_round=32, gamma=4,
                             local=LocalSpec(12, 1), store=store,
                             store_exchange=exchange,
                             reschedule_every_round=True,
                             pad_mediators_to=8, seed=0),
        mesh=mesh)
    for _ in range(ROUNDS):
        eng.run_round()
    jax.block_until_ready(eng.params)
    key = store if store != "sharded" else store + "-" + exchange
    results[key] = {
        "wan_bytes": eng.comm.total_bytes,
        "intra_pod_bytes": eng.comm.intra_pod_bytes,
        "store_stream_bytes": eng.comm.store_stream_bytes,
        "store_exchange_bytes": eng.comm.store_exchange_bytes,
        "per_device_bytes": eng.store.per_device_bytes(),
        "traces": eng.num_round_traces,
    }
    params[key] = eng.params
ref = params["replicated"]
for key, p in params.items():
    results[key]["bitwise_equal_to_replicated"] = all(
        bool((a == b).all())
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)))
    assert results[key]["bitwise_equal_to_replicated"], key
    assert results[key]["traces"] == 1, key
# the WAN ledger is invariant to placement -- the 82% claim's denominator
assert len({r["wan_bytes"] for r in results.values()}) == 1
# the ragged exchange beats the fixed-capacity all_gather on the wire
assert (results["sharded-ragged"]["store_exchange_bytes"]
        < results["sharded-gather"]["store_exchange_bytes"])
print("JSON:" + json.dumps(results))
"""


def bench_store(scale: E.Scale):
    """ROADMAP item 1 (million-client streaming store). Two parts:

    * ``store/K*`` -- round-time and bytes-moved curves over federation
      size K in {1e3, 1e4, 1e5, 1e6}, streaming host vs spilled stores
      over a lazy ``StreamingFederation`` (histograms only; samples
      synthesized per streamed client). Device residency is pinned by
      ``clients_per_round``, so ``per_device_bytes`` must not move with
      K -- the fixed-footprint acceptance bar.
    * ``store/policies`` -- 4-real-device subprocess: all four placement
      policies train bitwise-identically with one trace each, the WAN
      ledger is placement-invariant, and the ragged exchange moves
      strictly fewer intra-pod bytes than the historical all_gather.
    """
    import subprocess
    import sys
    import jax
    from repro.core import LocalSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.data.synthetic import (SyntheticSpec, StreamingFederation,
                                      federation_counts)
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = SyntheticSpec(num_classes=8, image_size=16)
    model = emnist_cnn(8, image_size=16)
    mesh = make_mediator_mesh(1)
    rounds_after_warm = 2
    out = {"curves": {}}
    for k in (1_000, 10_000, 100_000, 1_000_000):
        t0 = time.time()
        counts = federation_counts(k, spec.num_classes, seed=5)
        stream = StreamingFederation(spec, counts, batch_size=12, seed=5)
        gen_s = time.time() - t0
        row = {"federation_gen_s": gen_s}
        for store in ("host", "spilled"):
            eng = FLRoundEngine(
                model, adam(1e-3), stream,
                EngineConfig.astraea(clients_per_round=16, gamma=4,
                                     local=LocalSpec(12, 1), store=store,
                                     reschedule_every_round=True, seed=0),
                mesh=mesh)
            t0 = time.time()
            eng.run_round()                 # compile + first stream
            jax.block_until_ready(eng.params)
            warm_s = time.time() - t0
            t0 = time.time()
            for _ in range(rounds_after_warm):
                eng.run_round()
            jax.block_until_ready(eng.params)
            us = (time.time() - t0) / rounds_after_warm * 1e6
            stats = eng.store.stats()
            row[store] = {
                "us_per_round": us, "warm_s": warm_s,
                "per_device_bytes": stats["per_device_bytes"],
                "streamed_bytes": stats["streamed_bytes"],
                "stream_ledger_bytes": eng.comm.store_stream_bytes,
                "wan_bytes": eng.comm.total_bytes,
                "traces": eng.num_round_traces,
                "prefetch_hits": stats.get("prefetch_hits"),
                "cache_hit_rows": stats.get("cache_hit_rows"),
            }
            _emit(f"store/K{k}/{store}", us,
                  f"per_device_bytes={stats['per_device_bytes']};"
                  f"streamed_mb={stats['streamed_bytes'] / 2**20:.1f};"
                  f"traces={eng.num_round_traces};"
                  f"prefetch_hits={stats.get('prefetch_hits', '-')}")
        out["curves"][f"K{k}"] = row
    # the footprint must be set by clients_per_round, never by K
    foot = {r[s]["per_device_bytes"]
            for r in out["curves"].values() for s in ("host", "spilled")}
    assert len(foot) == 1, f"device footprint moved with K: {foot}"
    out["fixed_device_footprint"] = True
    _emit("store/fixed_footprint", 0.0,
          f"per_device_bytes={foot.pop()} across K=1e3..1e6")

    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _STORE_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    line = next(l for l in proc.stdout.splitlines() if l.startswith("JSON:"))
    policies = json.loads(line[len("JSON:"):])
    out["policies"] = policies
    ragged = policies["sharded-ragged"]["store_exchange_bytes"]
    gathered = policies["sharded-gather"]["store_exchange_bytes"]
    for key, r in policies.items():
        _emit(f"store/policies/{key}", 0.0,
              f"wan_mb={r['wan_bytes'] / 2**20:.2f};"
              f"exchange_mb={r['store_exchange_bytes'] / 2**20:.2f};"
              f"bitwise={r['bitwise_equal_to_replicated']};"
              f"traces={r['traces']}")
    _emit("store/ragged_vs_gather", 0.0,
          f"ragged_bytes={ragged:.0f};gather_bytes={gathered:.0f};"
          f"saved={1 - ragged / gathered:.1%} (4 devices, skewed schedule)")
    _save("store", out)


# ----------------------------------------------------------------------
# LoRA adapter-delta WAN exchange: bytes and round-time vs adapter rank
# ----------------------------------------------------------------------

def bench_lora(scale: E.Scale):
    """Parameter-efficient WAN exchange (models/lora.py mapping table) on
    the astraea engine: sweep adapter rank over {0, 1, 2, full} against
    the full-delta oracle on the tiny letterfreq federation.

    The evidence this bench commits is the acceptance bar of the LoRA
    subsystem, asserted here and diffed exactly by the perf gate:

    * exact byte accounting -- the ledger's ``wan_adapter_bytes`` must
      equal ``rounds * (2*c*E_m + 2*ceil(c/gamma)) * payload`` to the
      bit (the counters are integer-valued f64, so == is meaningful);
    * ``rank2_ratio_le_0p10`` -- at rank 2 the adapter legs ship <= 10%
      of their full-delta counterfactual;
    * ``full_rank_bitwise`` -- at full rank every entry degenerates to a
      dense effective tensor, so merged params are BITWISE equal to the
      no-LoRA oracle after the same rounds;
    * ``rank0_frozen`` -- rank 0 is an empty mapping: zero adapter bytes
      and a bit-frozen backbone;
    * one round trace and one merge trace per engine even with
      ``reschedule_every_round`` (the zero-retrace contract).
    """
    import dataclasses
    import math
    import jax
    from repro.core import LocalSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.models import lora as lora_lib
    from repro.models.cnn import emnist_cnn
    from repro.optim.optimizers import sgd

    rounds, c, gamma, em = 4, 8, 4, 1
    legs_per_round = 2 * c * em + 2 * math.ceil(c / gamma)
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    model = emnist_cnn(8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600,
                    test_samples=160, sizes="instagram",
                    global_dist="letterfreq", local="random", seed=0,
                    name="lora-ltrf")
    local = LocalSpec(batch_size=10, epochs=1)
    fr = lora_lib.full_rank(model.param_specs())

    def run(rank):
        cfg = EngineConfig.astraea(clients_per_round=c, gamma=gamma,
                                   local=local, mediator_epochs=em,
                                   reschedule_every_round=True,
                                   donate_params=False, seed=0,
                                   lora_rank=rank)
        eng = FLRoundEngine(model, sgd(0.05), fed, cfg)
        eng.run_round()                      # compile + first schedule
        jax.block_until_ready(eng.server_state)
        t0 = time.time()
        for _ in range(rounds - 1):
            eng.run_round()
        jax.block_until_ready(eng.server_state)
        us = (time.time() - t0) / (rounds - 1) * 1e6
        return eng, us

    oracle, oracle_us = run(None)
    oracle_params = jax.device_get(oracle.params)
    out = {"full_delta": {
        "us_per_round": oracle_us,
        "wan_bytes_per_round": oracle.comm.total_bytes / rounds,
        "traces": oracle.num_round_traces,
    }}
    assert oracle.num_round_traces == 1, oracle.num_round_traces
    _emit("lora/full_delta", oracle_us,
          f"wan_bytes_per_round={out['full_delta']['wan_bytes_per_round']:.0f};"
          f"traces={oracle.num_round_traces}")

    def bitwise(a, b):
        return all(jax.tree.leaves(jax.tree.map(
            lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
            jax.device_get(a), jax.device_get(b))))

    for rank in (0, 1, 2, fr):
        eng, us = run(rank)
        payload = eng.comm.adapter_payload_bytes
        # exact ledger accounting: every adapter leg at payload bytes, its
        # counterfactual at model bytes -- integer-valued f64, so ==
        want_adapter = rounds * legs_per_round * payload
        want_equiv = rounds * legs_per_round * eng.comm.model_bytes
        ledger_exact = (eng.comm.wan_adapter_bytes == want_adapter
                        and eng.comm.wan_adapter_full_equiv_bytes == want_equiv
                        and eng.comm.total_bytes == want_adapter)
        assert ledger_exact, (rank, eng.comm.wan_adapter_bytes, want_adapter)
        assert eng.num_round_traces == 1, (rank, eng.num_round_traces)
        merged = eng.merged_params()
        assert eng.num_merge_traces == 1, (rank, eng.num_merge_traces)
        ratio = eng.comm.adapter_reduction_ratio
        row = {
            "adapter_params": lora_lib.num_trainable_params(eng._lora_mapping),
            "adapter_payload_bytes": payload,
            "wan_adapter_bytes_per_round": legs_per_round * payload,
            "wan_full_equiv_bytes_per_round":
                legs_per_round * eng.comm.model_bytes,
            "ratio": ratio,
            "us_per_round": us,
            "traces": eng.num_round_traces,
            "ledger_exact": ledger_exact,
        }
        if rank == 0:
            row["rank0_frozen"] = bitwise(merged, eng.params)
            assert row["rank0_frozen"] and payload == 0, (payload,)
        if rank == 2:
            row["rank2_ratio_le_0p10"] = bool(ratio <= 0.10)
            assert row["rank2_ratio_le_0p10"], ratio
        if rank == fr:
            # all entries dense at full rank: merged params must be
            # bitwise-equal to the no-LoRA oracle after identical rounds
            row["full_rank_bitwise"] = bitwise(merged, oracle_params)
            assert row["full_rank_bitwise"]
        out[f"rank{rank}"] = row
        _emit(f"lora/rank{rank}", us,
              f"adapter_bytes_per_round={row['wan_adapter_bytes_per_round']:.0f};"
              f"ratio={ratio:.4f};payload={payload:.0f};"
              f"traces={eng.num_round_traces};ledger_exact={ledger_exact}")
    out["full_rank"] = fr
    _save("lora", out)


# ----------------------------------------------------------------------
# Kernel microbenchmarks (wall time per call, interpret mode on CPU)
# ----------------------------------------------------------------------

def bench_kernels(scale: E.Scale):
    """Per-kernel wall time + the analytic roofline ledger.

    Every Pallas kernel that carries a ``pl.CostEstimate`` gets its
    analytic FLOPs/bytes, the v5e roofline bound (``roofline_us``, which
    wall it sits against) and the achieved fraction recorded next to the
    measured time in ``kernels.json``. On this CPU container the kernels
    run in interpret mode, so ``achieved_frac`` is honest-but-tiny -- the
    ``interpret`` tag (per row AND in ``_meta``) is what stops the perf
    gate from ever comparing those numbers against Mosaic baselines.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import scheduling
    from repro.kernels import ops, ref
    from repro.kernels import affine_warp as _aw
    from repro.kernels import fedavg_agg as _fa
    from repro.kernels import flash_attention as _fla
    from repro.kernels import kld_score as _kl
    from repro.kernels import ssd_chunk as _sc
    from repro.roofline import kernel_roofline, achieved_fraction
    key = jax.random.PRNGKey(0)
    interp = jax.default_backend() != "tpu"
    out = {}

    def timeit(fn, *args, n=5):
        jax.block_until_ready(fn(*args))
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    def record(name, us, ref_us, shape, cost=None):
        row = {"us": us, "shape": shape, "interpret": interp}
        derived = f"shape={shape}"
        if ref_us is not None:
            row["ref_us"] = ref_us
            derived = f"ref_us={ref_us:.1f};" + derived
        if cost is not None:
            roof = kernel_roofline(cost.flops, cost.bytes_accessed)
            row.update({
                "flops": float(cost.flops),
                "bytes": float(cost.bytes_accessed),
                "roofline_us": roof["roofline_s"] * 1e6,
                "bound": roof["bound"],
                "achieved_frac": achieved_fraction(us * 1e-6,
                                                   roof["roofline_s"]),
            })
            derived += (f";roofline_us={row['roofline_us']:.3f};"
                        f"bound={row['bound']};"
                        f"achieved={row['achieved_frac']:.1e};"
                        f"interpret={interp}")
        out[name] = row
        _emit(f"kernels/{name}", us, derived)

    m, n = 8, 1 << 16
    d = jax.random.normal(key, (m, n), jnp.float32)
    w = jnp.arange(1.0, m + 1.0)
    us_k = timeit(lambda a, b: ops.fedavg_agg(a, b), d, w)
    us_r = timeit(lambda a, b: ref.fedavg_agg(a, b), d, w)
    record("fedavg_agg", us_k, us_r, f"{m}x{n}",
           _fa.cost_estimate(m, n, 4, 4))

    kk, c = 512, 47
    med = jax.random.uniform(key, (c,)) * 100
    cli = jax.random.uniform(key, (kk, c)) * 50
    us_k = timeit(lambda a, b: ops.kld_score(a, b), med, cli)
    us_r = timeit(lambda a, b: ref.kld_score(a, b), med, cli)
    record("kld_score", us_k, us_r, f"{kk}x{c}", _kl.score_cost(1, kk, c))

    mm = 16
    meds = jax.random.uniform(key, (mm, c)) * 100
    us_k = timeit(lambda a, b: ops.kld_score_matrix(a, b), meds, cli)
    us_r = timeit(lambda a, b: ref.kld_score_matrix(a, b), meds, cli)
    record("kld_score_matrix", us_k, us_r, f"{mm}x{kk}x{c}",
           _kl.score_cost(mm, kk, c))

    # the one-launch Alg. 3 pass vs the XLA lax.scan it replaces
    gk, gamma = 128, 8
    counts = jnp.floor(jax.random.uniform(key, (gk, c)) * 20)
    us_k = timeit(lambda a: ops.kld_greedy_picks(a, gamma), counts)
    us_r = timeit(lambda a: scheduling._greedy_picks(a, gamma), counts)
    record("kld_greedy_picks", us_k, us_r, f"K{gk}xC{c}g{gamma}",
           _kl.greedy_cost(gk, c))

    # Alg. 2 augmentation primitive -- a mobile-vision batch
    wb, wh, wc = 32, 28, 1
    from repro.core.augmentation import warp_params
    imgs = jax.random.normal(key, (wb, wh, wh, wc), jnp.float32)
    mats, trans = warp_params(jax.random.fold_in(key, 7), wb)
    us_k = timeit(lambda a, b2, c2: ops.affine_warp(a, b2, c2),
                  imgs, mats, trans)
    us_r = timeit(lambda a, b2, c2: ref.affine_warp(a, b2, c2),
                  imgs, mats, trans)
    record("affine_warp", us_k, us_r, f"b{wb}x{wh}x{wh}x{wc}",
           _aw.cost_estimate(wb, wh, wh, wc, 4))

    q = jax.random.normal(key, (1, 512, 4, 64))
    k2 = jax.random.normal(key, (1, 512, 2, 64))
    v2 = jax.random.normal(key, (1, 512, 2, 64))
    us_k = timeit(lambda a, b, c: ops.flash_attention(a, b, c), q, k2, v2)
    # ops repeats the 2 GQA kv heads to 4 before the kernel launch
    record("flash_attention", us_k, None, "s512h4d64",
           _fla.cost_estimate(1, 4, 512, 512, 64, 4))

    b, nc, L, h, p, n = 2, 8, 64, 4, 64, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, nc, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, L, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, nc, L, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, nc, L, n)) * 0.5
    us_k = timeit(lambda *a: ops.ssd_chunk(*a)[0], x, dt, A, Bm, Cm)
    us_r = timeit(lambda *a: ref.ssd_chunk(*a)[0], x, dt, A, Bm, Cm)
    record("ssd_chunk", us_k, us_r, "b2xc8xL64xh4",
           _sc.cost_estimate(b, nc, L, h, p, n))
    _save("kernels", out)


# ----------------------------------------------------------------------
# Roofline rows (from the dry-run artifacts)
# ----------------------------------------------------------------------

def bench_roofline(scale: E.Scale):
    for mesh in ("single16x16", "pod2x16x16"):
        for name, us, derived in R.csv_rows(mesh):
            _emit(name, us, derived)
    # post-§Perf optimized stack (blockwise/local-window attention,
    # token-parallel MoE) -- before/after table in EXPERIMENTS.md
    for mesh in ("single16x16", "pod2x16x16"):
        for name, us, derived in R.csv_rows(mesh, optimized=True):
            _emit(name, us, derived)


ALL = {
    "motivation": bench_motivation,
    "accuracy": bench_accuracy,
    "alpha_sweep": bench_alpha_sweep,
    "kld": bench_kld,
    "c_gamma": bench_c_gamma,
    "epochs": bench_epochs,
    "communication": bench_communication,
    "engine": bench_engine,
    "store": bench_store,
    "augmentation": bench_augmentation,
    "agg": bench_agg,
    "async": bench_async,
    "lora": bench_lora,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--store", default="replicated,sharded,host",
                    help="comma-separated ClientStore policies for the "
                         "engine benchmark (replicated,sharded,host)")
    ap.add_argument("--results-dir", default=None,
                    help="write result JSONs here instead of "
                         "experiments/results (CI: fresh evidence for "
                         "benchmarks/gate.py to diff against baselines)")
    ap.add_argument("--trace-dir", default=None,
                    help="enable obs.Telemetry on the engine benchmarks and "
                         "write span JSONL / trace.json / Prometheus text "
                         "per bench arm under this directory (default: "
                         "tracing off, zero-cost no-op stubs)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.results_dir:
        global RESULTS_DIR
        RESULTS_DIR = args.results_dir
    if args.trace_dir:
        global TRACE_DIR
        TRACE_DIR = args.trace_dir
    scale = E.FULL if args.full else E.DEFAULT
    names = args.only.split(",") if args.only else list(ALL)
    benches = dict(ALL)
    benches["engine"] = functools.partial(
        bench_engine, stores=tuple(args.store.split(",")))
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        benches[name](scale)
        print(f"# {name} finished in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
