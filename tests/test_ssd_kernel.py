"""Pallas SSD intra-chunk kernel vs oracle + end-to-end composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models import ssm


def _inputs(key, b=2, nc=3, L=32, h=4, p=16, n=8, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, nc, L, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, L, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, nc, L, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, nc, L, n)) * 0.5).astype(dtype)
    return x, dt, A, B, C


@given(L=st.sampled_from([8, 16, 64]), h=st.sampled_from([1, 3]),
       p=st.sampled_from([8, 64]), n=st.sampled_from([8, 32]),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_ssd_chunk_matches_oracle(L, h, p, n, seed):
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(seed), L=L, h=h, p=p, n=n)
    y, S, g = ops.ssd_chunk(x, dt, A, B, C)
    yr, Sr, gr = ref.ssd_chunk(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=3e-5, atol=3e-6)


def test_ssd_chunk_bf16_inputs(key):
    x, dt, A, B, C = _inputs(key, dtype=jnp.bfloat16)
    y, S, g = ops.ssd_chunk(x, dt, A, B, C)
    yr, Sr, gr = ref.ssd_chunk(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=4e-2, atol=4e-2)


def test_kernel_composes_to_full_ssd(key):
    """kernel(y_diag, S, g) + inter-chunk scan + y_off == ssd_chunked."""
    b, nc, L, h, p, n = 2, 4, 16, 3, 8, 8
    x, dt, A, B, C = _inputs(key, b=b, nc=nc, L=L, h=h, p=p, n=n)
    y, S, g = ops.ssd_chunk(x, dt, A, B, C)

    f32 = jnp.float32
    cum = jnp.cumsum(dt.astype(f32) * A.astype(f32), axis=2)

    def body(hstate, inp):
        s_c, g_c = inp
        prev = hstate
        return g_c[..., None, None] * hstate + s_c, prev

    Sm = jnp.moveaxis(jnp.swapaxes(S, -1, -2), 1, 0)    # (nc, b, h, p, n)
    gm = jnp.moveaxis(g, 1, 0)
    final, hprev = jax.lax.scan(body, jnp.zeros((b, h, p, n)), (Sm, gm))
    hprev = jnp.moveaxis(hprev, 0, 1)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", C.astype(f32),
                       jnp.exp(cum), hprev)
    y_tot = (y.astype(f32) + y_off).reshape(b, nc * L, h, p)

    y_ref, state_ref = ssm.ssd_chunked(
        x.reshape(b, nc * L, h, p), dt.reshape(b, nc * L, h), A,
        B.reshape(b, nc * L, n), C.reshape(b, nc * L, n), jnp.zeros((h,)), L)
    np.testing.assert_allclose(np.asarray(y_tot), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state_ref),
                               rtol=3e-4, atol=3e-4)
