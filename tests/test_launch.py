"""Launcher substrate: input specs, microbatch heuristic, skip logic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import INPUT_SHAPES, input_specs
from repro.launch.compat import abstract_mesh
from repro.launch.steps import suggest_microbatches
from repro.models import transformer as T


@pytest.mark.parametrize("aid", C.ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_cover_all_pairs(aid, shape_name):
    """Every (arch x shape) has well-formed ShapeDtypeStruct inputs."""
    cfg = C.get(aid)
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    assert "batch" in specs
    toks = specs["batch"]["tokens"]
    assert toks.dtype == jnp.int32
    assert toks.shape[0] == shape.global_batch
    if shape.kind == "train":
        assert specs["batch"]["labels"].shape == toks.shape
        if cfg.arch_type == "vlm":
            v = specs["batch"]["vision_embeds"]
            assert v.shape[1] + toks.shape[1] == shape.seq_len
    if shape.kind == "decode":
        assert toks.shape[1] == 1
        assert "cache" in specs
        for leaf in jax.tree.leaves(specs["cache"]):
            assert leaf.shape[0] == cfg.n_layers   # stacked layer axis
    # nothing was allocated
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_suggest_microbatches_scales_with_model():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    small = suggest_microbatches(C.get("whisper-base"), 256, 4096, mesh)
    big = suggest_microbatches(C.get("grok-1-314b"), 256, 4096, mesh)
    assert small <= big
    assert big >= 2                        # grok needs accumulation
    assert 256 % big == 0 or big <= 256 // 16


def test_decode_cache_sizes_match_shapes():
    cfg = C.get("h2o-danube-1.8b")         # SWA: window-sized cache
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 524_288))
    k = cache["attn"]["k"]
    assert k.shape[2] == cfg.sliding_window  # ring buffer, not 524288
    cfg2 = C.get("mamba2-370m")            # SSM: O(1) state
    cache2 = jax.eval_shape(lambda: T.init_cache(cfg2, 4, 524_288))
    assert "attn" not in cache2
    assert cache2["state"].shape == (cfg2.n_layers, 4, cfg2.ssm_heads,
                                     cfg2.ssm_head_dim, cfg2.ssm_state)


def test_long500k_skip_logic():
    from repro.models.transformer import ArchConfig
    sub = [a for a in C.ARCH_IDS if C.get(a).sub_quadratic]
    assert set(sub) == {"mamba2-370m", "hymba-1.5b", "h2o-danube-1.8b"}
