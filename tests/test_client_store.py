"""ClientStore placement policies: equivalence, locality, fixed-M compiles.

The data-layer contract (core/client_store.py): ``replicated``, ``sharded``
and ``host`` stores feed bit-identical per-slot tensors into the same round
program, so trajectories must be bitwise equal at any fixed mesh size --
and, with the batch-size-invariant ``row_exec="map"``, across *different*
mesh sizes too (the acceptance claim: sharded on a 4-device mesh ==
replicated on 1 device, exactly). Fixed-M compilation: reschedules must
never re-trace the round executable."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LocalSpec, scheduling
from repro.core.client_store import (HostStore, MmapClients, PackedClients,
                                     ShardedStore, SpilledHostStore,
                                     build_client_store)
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _run(model, fed, cfg, rounds=2, mesh=None):
    eng = FLRoundEngine(model, adam(1e-3), fed, cfg,
                        mesh=mesh or make_mediator_mesh(1))
    for _ in range(rounds):
        eng.run_round()
    return eng


def test_stores_bitwise_identical_on_one_device(model, tiny_federation):
    """sharded + host == replicated, bitwise, incl. across a reschedule."""
    base = EngineConfig.astraea(clients_per_round=6, gamma=3,
                                local=LocalSpec(10, 1), seed=0,
                                pad_mediators_to=2,
                                reschedule_every_round=True)
    ref = _run(model, tiny_federation, base)
    for store in ("sharded", "host", "spilled"):
        eng = _run(model, tiny_federation,
                   dataclasses.replace(base, store=store))
        _params_equal(eng, ref)
        assert eng.num_round_traces == 1        # reschedule didn't re-jit


def test_fedavg_stores_bitwise_identical(model, tiny_federation):
    """The gamma=1 weight-agg path: per-round random reschedules, all
    stores, one trace."""
    base = EngineConfig.fedavg(clients_per_round=4, local=LocalSpec(10, 1),
                               seed=0, pad_mediators_to=4)
    ref = _run(model, tiny_federation, base, rounds=3)
    for store in ("sharded", "host", "spilled"):
        eng = _run(model, tiny_federation,
                   dataclasses.replace(base, store=store), rounds=3)
        _params_equal(eng, ref)
        assert eng.num_schedule_packs == 3 and eng.num_round_traces == 1


def test_fixed_m_round_traced_exactly_once(model, tiny_federation):
    """pad_mediators_to floors M above the natural schedule size; three
    reschedules reuse the one executable (the fixed-M compilation claim)."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=5,   # > ceil(6/3) = 2
                               reschedule_every_round=True)
    eng = _run(model, tiny_federation, cfg, rounds=3)
    assert eng.num_schedule_packs == 3
    assert eng.num_round_traces == 1


def test_trainers_default_fixed_m_and_store(tiny_federation):
    """AstraeaTrainer/FedAvgTrainer wire pad_mediators_to=ceil(c/gamma)
    and pass the store policy through to the engine."""
    from repro.core.astraea import AstraeaTrainer
    from repro.core.fedavg import FedAvgTrainer
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=6, gamma=4, local=LocalSpec(10, 1),
                        alpha=None, store="host", seed=0)
    assert tr.engine.cfg.pad_mediators_to == 2      # ceil(6/4)
    assert tr.engine.store.policy == "host"
    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=5, local=LocalSpec(10, 1),
                       store="sharded", seed=0)
    assert fa.engine.cfg.pad_mediators_to == 5      # gamma=1 -> c
    assert fa.engine.store.policy == "sharded"
    fa.run_round()
    fa.run_round()
    assert fa.engine.num_round_traces == 1


def test_engine_rejects_unknown_store(tiny_federation):
    with pytest.raises(ValueError, match="client-store policy"):
        EngineConfig.astraea(clients_per_round=4, gamma=2,
                             local=LocalSpec(10, 1), store="sparse")


def test_host_store_capacity_and_streaming(tiny_federation):
    """Host store keeps O(c) bytes on device, streams once per reschedule,
    and rejects schedules beyond its capacity."""
    sizes = [x.shape[0] for x in tiny_federation.client_images]
    pad = ((max(sizes) + 9) // 10) * 10
    xs, ys, mask = tiny_federation.padded(pad)
    mesh = make_mediator_mesh(1)
    host = build_client_store("host", xs, ys, mask, mesh, capacity=4)
    rep = build_client_store("replicated", xs, ys, mask, mesh)
    k = tiny_federation.num_clients
    assert host.per_device_bytes() * k == rep.per_device_bytes() * 4
    idx = np.array([[0, 3], [7, 1]], np.int32)
    slot = np.ones((2, 2), np.float32)
    before = host._streamed_bytes
    host.plan(idx, slot)
    assert host._streamed_bytes - before == host.per_device_bytes()
    too_many = np.arange(10, dtype=np.int32).reshape(5, 2)
    with pytest.raises(ValueError, match="capacity"):
        host.plan(too_many, np.ones((5, 2), np.float32))


def test_sharded_store_plan_single_shard_all_local(tiny_federation):
    """On a 1-device mesh every slot resolves against the local shard and
    the serve/all_gather machinery stays empty."""
    sizes = [x.shape[0] for x in tiny_federation.client_images]
    pad = ((max(sizes) + 9) // 10) * 10
    xs, ys, mask = tiny_federation.padded(pad)
    store = ShardedStore(xs, ys, mask, make_mediator_mesh(1))
    assert store._k_local == tiny_federation.num_clients  # 1 shard owns all
    idx = np.array([[0, 5], [7, 7]], np.int32)
    slot = np.ones((2, 2), np.float32)
    _, (serve, loc, lpos, rpos) = store.plan(idx, slot)
    assert bool(np.all(np.asarray(loc)))        # n=1: everything local
    np.testing.assert_array_equal(np.asarray(lpos), idx)
    assert np.asarray(rpos).max() == 0


def test_sharded_store_plan_remote_routing_and_dedup():
    """The remote branch of ShardedStore.plan, host-side on a simulated
    4-shard layout (no multi-device mesh needed: plan() is pure host
    index math): owner routing, serve-list dedup, and rpos composition."""
    store = ShardedStore.__new__(ShardedStore)   # skip device placement
    store._n, store._k_local = 4, 3              # shards own [0..2],[3..5],...
    store._x = store._y = store._m = None        # data args unused here
    store.last_placement_stats = {}
    # M_pad=4 rows -> one row per shard; F = min(4*2, 3) = 3
    idx = np.array([[0, 4],     # row 0/shard 0: cid 0 local, cid 4 remote
                    [4, 2],     # row 1/shard 1: cid 4 LOCAL here, cid 2 remote
                    [7, 7],     # row 2/shard 2: cid 7 local twice
                    [0, 4]],    # row 3/shard 3: both remote, cid 4 again
                   np.int32)
    slot = np.ones((4, 2), np.float32)
    _, (serve, loc, lpos, rpos) = store.plan(idx, slot)
    serve, loc, lpos, rpos = map(np.asarray, (serve, loc, lpos, rpos))
    f = 3
    expect_loc = np.array([[True, False], [True, False],
                           [True, True], [False, False]])
    np.testing.assert_array_equal(loc, expect_loc)
    # local reads use shard-local rows (cid % k_local)
    assert lpos[0, 0] == 0 and lpos[1, 0] == 1 and lpos[2, 0] == 1
    # remote reads point at the owner's serve segment: rpos = owner*F + j
    assert rpos[0, 1] == 1 * f + serve_pos(serve, 1, 4 % 3)
    assert rpos[1, 1] == 0 * f + serve_pos(serve, 0, 2)
    # dedup: cid 4, needed remotely by shards 0 and 3, is served once
    assert rpos[3, 1] == rpos[0, 1]
    assert rpos[3, 0] == 0 * f + serve_pos(serve, 0, 0)
    assert rpos[3, 0] != rpos[3, 1]
    # occupied = distinct remote cids {4, 2, 0}
    assert store.last_placement_stats["serve_occupied"] == 3
    assert store.last_placement_stats["serve_capacity"] == 4 * f


def serve_pos(serve, owner, local_row):
    js = np.flatnonzero(np.asarray(serve)[owner] == local_row)
    assert js.size >= 1
    return int(js[0])


def test_place_mediators_prefers_owning_shard():
    """Locality pass: mediators land on the shard holding their clients;
    capacity forces ties to spill deterministically."""
    # 8 clients, 2 shards of 4: shard0 owns 0-3, shard1 owns 4-7
    owner = lambda cid: cid // 4
    groups = [[0, 1], [4, 5], [2, 3], [6, 7]]
    rows, stats = scheduling.place_mediators(groups, 2, 2, owner)
    assert sorted(rows.tolist()) == [0, 1, 2, 3]
    # rows 0-1 on shard0, rows 2-3 on shard1
    assert {rows[0], rows[1]} == {0, 2} and {rows[2], rows[3]} == {1, 3}
    assert stats["remote_fetches"] == 0 and stats["local_fetches"] == 8
    # overload one shard: 3 mediators want shard0, capacity 2 -> 1 spills
    groups = [[0, 1], [2, 3], [0, 2], [4, 5]]
    rows, stats = scheduling.place_mediators(groups, 2, 2, owner)
    assert stats["remote_fetches"] == 2
    with pytest.raises(ValueError, match="do not fit"):
        scheduling.place_mediators([[0]] * 5, 2, 2, owner)


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)
    base = EngineConfig.astraea(clients_per_round=6, gamma=3,
                                local=LocalSpec(10, 1), seed=0,
                                pad_mediators_to=4,
                                reschedule_every_round=True)

    def run(store, nd, row_exec="vmap", exchange="ragged"):
        cfg = dataclasses.replace(base, store=store, row_exec=row_exec,
                                  store_exchange=exchange)
        e = FLRoundEngine(model, adam(1e-3), fed, cfg,
                          mesh=make_mediator_mesh(nd))
        e.run_round()
        e.run_round()
        return e

    def check(a, b):
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # (1) fixed 4-device mesh: every store policy bitwise identical, and
    # the sharded store under BOTH exchange modes
    r4, s4, h4 = run("replicated", 4), run("sharded", 4), run("host", 4)
    check(s4, r4)
    check(h4, r4)
    g4 = run("sharded", 4, exchange="gather")
    check(g4, r4)
    sp4 = run("spilled", 4)
    check(sp4, r4)
    # per-round reschedules mean the engine prefetched round 2's schedule
    # while round 1 computed -- and the prefetch was used
    assert sp4.store.prefetch_hits >= 1, sp4.store.stats()
    assert sp4.store.prefetch_misses == 0
    # the ragged exchange never ships more than the fixed all_gather
    assert s4.store.exchange_bytes_per_round <= g4.store.exchange_bytes_per_round
    assert g4.store.exchange_bytes_per_round > 0

    # (1b) async S=0 over the spill tier: waves + prefetch overlap still
    # reproduce the synchronous trajectory bitwise
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.staleness import StragglerSpec
    acfg = dataclasses.replace(base, store="spilled", donate_params=False)
    sync = FLRoundEngine(model, adam(1e-3), fed, acfg,
                         mesh=make_mediator_mesh(4))
    sync.run_round(); sync.run_round()
    eng = FLRoundEngine(model, adam(1e-3), fed, acfg,
                        mesh=make_mediator_mesh(4))
    an = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=0, wave_size=1,
                                         straggler=StragglerSpec(
                                             model="lognormal", seed=3)))
    an.run_round(); an.run_round()
    check(sync, an.engine)
    assert an.engine.num_round_traces == 1

    # (2) cross-mesh: sharded on 4 devices == replicated on 1 device,
    # bitwise, under the batch-size-invariant row executor
    check(run("sharded", 4, "map"), run("replicated", 1, "map"))

    # (3) per-device client-store bytes reduced ~4x, verified against the
    # actual device buffers (addressable shard inspection)
    assert s4.store.per_device_bytes() * 4 == r4.store.per_device_bytes()
    for arr in (s4.store._x, s4.store._y, s4.store._m):
        shards = arr.addressable_shards
        assert len(shards) == 4
        assert all(s.data.shape[0] == arr.shape[0] // 4 for s in shards)
        assert all(s.data.nbytes * 4 == arr.nbytes for s in shards)

    # (4) the per-round reschedules never re-traced any round executable
    for e in (r4, s4, h4):
        assert e.num_round_traces == 1, e.num_round_traces
        assert e.num_schedule_packs == 2

    # (5) locality pass ran and accounted for every scheduled client
    # (store placement keys ride last_schedule_stats under the store_
    # namespace so they can never clobber the scheduler's own keys)
    st = s4.last_schedule_stats
    assert st["store_local_fetches"] + st["store_remote_fetches"] \
        == st["store_total_fetches"]
    assert st["store_total_fetches"] == 6
    print("OK")
""")


def test_sharded_and_host_stores_multi_device(tmp_path):
    """The acceptance claims on a real 4-device mesh (subprocess: the
    device count must be forced before jax initializes)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout


# --------------------------------------------------------------------------
# ShardedStore.plan property tests: adversarial schedules, both exchanges.
# plan() is pure host index math, so a simulated n-shard layout needs no
# devices; _simulate_slot_values re-executes the slot_data exchange in
# numpy on data where shard row j of owner o holds the value o*k_local+j
# (i.e. the global cid) -- reconstruction must return idx wherever the
# slot mask is active, which is exactly the brute-force gather oracle.
# --------------------------------------------------------------------------

def _mk_sharded(n, k_local, exchange):
    store = ShardedStore.__new__(ShardedStore)   # skip device placement
    store._n, store._k_local = n, k_local
    store._x = store._y = store._m = None
    store._slice_nbytes = 8
    store.exchange = exchange
    store.last_placement_stats = {}
    return store


def _simulate_slot_values(store, plan_args, m_pad, gamma):
    n, k_local = store._n, store._k_local
    m_local = max(1, m_pad // n)
    route, loc, lpos, rpos = (np.asarray(a) for a in plan_args)
    readers = np.arange(m_pad)[:, None] // m_local
    local_vals = readers * k_local + lpos
    if store.exchange == "gather":
        f = route.shape[1]
        gathered = (np.arange(n)[:, None] * k_local + route).reshape(-1)
        remote_vals = gathered[rpos]
    else:
        r_cap = route.shape[2]
        recv = np.zeros((n, max(n - 1, 1) * r_cap), np.int64)
        for d in range(n):
            for s in range(1, n):       # hop s delivers shard (d-s)%n's list
                o = (d - s) % n
                recv[d, (s - 1) * r_cap:s * r_cap] = \
                    o * k_local + route[o, s - 1]
        remote_vals = recv[readers, rpos]
    return np.where(loc, local_vals, remote_vals)


def _check_plan(store, idx, slot):
    """Plan + brute-force reconstruction + static-capacity invariants."""
    _, plan_args = store.plan(idx, slot)
    sim = _simulate_slot_values(store, plan_args, *idx.shape)
    active = np.asarray(slot) > 0
    np.testing.assert_array_equal(sim[active], idx[active].astype(np.int64))
    stats = store.last_placement_stats
    assert 0 <= stats["serve_occupied"] <= stats["serve_capacity"]
    loc, rpos = np.asarray(plan_args[1]), np.asarray(plan_args[3])
    if store.exchange == "gather":
        bound = store._n * np.asarray(plan_args[0]).shape[1]
    else:
        bound = max(store._n - 1, 1) * np.asarray(plan_args[0]).shape[2]
    assert rpos[~loc].max(initial=0) < bound     # serve fill never overflows
    return plan_args, stats


@pytest.mark.parametrize("exchange", ["gather", "ragged"])
def test_plan_all_remote_schedule(exchange):
    """Adversarial: every active slot reads a non-owned client -- no local
    reads, every value reconstructs through the exchange buffers, and the
    occupied count equals the dedup key count."""
    n, k_local, gamma = 4, 3, 2
    store = _mk_sharded(n, k_local, exchange)
    m_pad = 4                                    # m_local=1: reader = row
    rng_ = np.random.default_rng(0)
    idx = np.empty((m_pad, gamma), np.int32)
    for r in range(m_pad):
        others = [c for c in range(n * k_local) if c // k_local != r]
        idx[r] = rng_.choice(others, gamma)
    slot = np.ones((m_pad, gamma), np.float32)
    plan_args, stats = _check_plan(store, idx, slot)
    assert not np.asarray(plan_args[1]).any()    # loc: nothing local
    if exchange == "gather":
        assert stats["serve_occupied"] == np.unique(idx).size
    else:                       # per-pair dedup: distinct (reader, cid) here
        assert stats["serve_occupied"] == \
            len({(r, int(c)) for r in range(m_pad) for c in idx[r]})


@pytest.mark.parametrize("exchange", ["gather", "ragged"])
def test_plan_all_duplicate_schedule(exchange):
    """Adversarial: every slot reads the SAME client. Dedup collapses the
    exchange to one slice (gather) / one slice per remote reader (ragged)."""
    n, k_local, gamma = 4, 3, 3
    store = _mk_sharded(n, k_local, exchange)
    m_pad = 8                                    # m_local = 2
    hot = 4                                      # owned by shard 1
    idx = np.full((m_pad, gamma), hot, np.int32)
    slot = np.ones((m_pad, gamma), np.float32)
    plan_args, stats = _check_plan(store, idx, slot)
    loc = np.asarray(plan_args[1])
    assert loc[2:4].all() and not loc[[0, 1, 4, 5, 6, 7]].any()
    if exchange == "gather":
        assert stats["serve_occupied"] == 1
        rpos = np.asarray(plan_args[3])
        assert np.unique(rpos[~loc]).size == 1   # every reader shares the slot
    else:
        assert stats["serve_occupied"] == n - 1  # one per (owner, reader) pair


@pytest.mark.parametrize("exchange", ["gather", "ragged"])
def test_plan_single_owner_hot_shard(exchange):
    """Adversarial: all scheduled clients live on shard 0 (hot shard); the
    serve fill stays within the static capacity and dedup still holds."""
    n, k_local, gamma = 4, 8, 2
    store = _mk_sharded(n, k_local, exchange)
    m_pad = 4
    rng_ = np.random.default_rng(1)
    idx = rng_.integers(0, k_local, (m_pad, gamma)).astype(np.int32)
    slot = np.ones((m_pad, gamma), np.float32)
    plan_args, stats = _check_plan(store, idx, slot)
    remote_cids = {int(c) for r in range(1, m_pad) for c in idx[r]}
    if exchange == "gather":
        f = max(1, min(m_pad * gamma, k_local))
        assert stats["serve_capacity"] == n * f
        assert stats["serve_occupied"] == len(remote_cids) <= f
    else:
        r_cap = max(1, min((m_pad // n) * gamma, k_local))
        assert np.asarray(plan_args[0]).shape == (n, n - 1, r_cap)
        assert stats["serve_occupied"] == \
            len({(r, int(c)) for r in range(1, m_pad) for c in idx[r]})


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4]),
       st.integers(1, 5), st.integers(1, 3), st.integers(1, 3),
       st.sampled_from(["gather", "ragged"]))
def test_plan_random_schedules_reconstruct_bruteforce(seed, n, k_local,
                                                      m_local, gamma,
                                                      exchange):
    """Random meshes x schedules x slot masks: the reconstructed slot
    values always equal the brute-force gather of idx."""
    rng_ = np.random.default_rng(seed)
    m_pad = n * m_local
    idx = rng_.integers(0, n * k_local, (m_pad, gamma)).astype(np.int32)
    slot = (rng_.random((m_pad, gamma)) < 0.7).astype(np.float32)
    _check_plan(_mk_sharded(n, k_local, exchange), idx, slot)


def test_ragged_exchange_cheaper_on_locality_skewed_schedule():
    """The accounting claim, host-side: on a schedule where most reads are
    local (one hot remote client), the ragged plan charges strictly fewer
    interconnect bytes than the fixed-capacity all_gather."""
    n, k_local, gamma, m_pad = 4, 8, 2, 8
    idx = ((np.arange(8)[:, None] // 2) * k_local +
           np.arange(2)[None, :]).astype(np.int32)   # every read local...
    idx[7, 1] = 3                                    # ...but one remote read
    slot = np.ones((m_pad, gamma), np.float32)
    ragged = _mk_sharded(n, k_local, "ragged")
    gather = _mk_sharded(n, k_local, "gather")
    _check_plan(ragged, idx, slot)
    _check_plan(gather, idx, slot)
    assert ragged.exchange_bytes_per_round == 1 * ragged._slice_nbytes
    f = max(1, min(m_pad * gamma, k_local))
    assert gather.exchange_bytes_per_round == n * f * (n - 1) * 8
    assert ragged.exchange_bytes_per_round < gather.exchange_bytes_per_round


# --------------------------------------------------------------------------
# Spill tier: mmap row source, RAM cache, async prefetch correctness
# --------------------------------------------------------------------------

def _packed_arrays(fed):
    sizes = [x.shape[0] for x in fed.client_images]
    pad = ((max(sizes) + 9) // 10) * 10
    return fed.padded(pad)


def test_mmap_clients_matches_ram_source(tiny_federation, tmp_path):
    """The disk tier is a bit-exact row source: specs, per-client bytes
    and fancy-indexed rows all match the RAM-packed federation."""
    xs, ys, mask = _packed_arrays(tiny_federation)
    src = MmapClients(xs, ys, mask, str(tmp_path / "spill"))
    ram = PackedClients(xs, ys, mask)
    assert src.num_clients == ram.num_clients
    assert src.row_specs == ram.row_specs
    assert src.nbytes_per_client == ram.nbytes_per_client
    ids = np.array([3, 0, 7, 11])
    for a, b in zip(src.rows(ids), ram.rows(ids)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spilled_prefetch_bit_identical_to_sync(tiny_federation, tmp_path):
    """Prefetched staging is byte-equal to a synchronous stream of the
    same schedule: the overlap changes WHEN bytes move, never which."""
    xs, ys, mask = _packed_arrays(tiny_federation)
    mesh = make_mediator_mesh(1)
    mk = lambda d: build_client_store("spilled", xs, ys, mask, mesh,
                                      capacity=4,
                                      spill_dir=str(tmp_path / d))
    idx_a = np.array([[0, 3], [7, 1]], np.int32)
    idx_b = np.array([[7, 2], [5, 3]], np.int32)     # reuses clients 3 and 7
    slot = np.ones((2, 2), np.float32)

    warm = mk("a")
    warm.plan(idx_a, slot)                  # populates the RAM cache
    warm.prefetch(idx_b)                    # background staging
    data_pre, (remap_pre,) = warm.plan(idx_b, slot)
    assert warm.prefetch_hits == 1 and warm.prefetch_misses == 0
    assert warm.cache_hit_rows == 2         # 3 and 7 came from RAM, not disk
    assert warm.num_streams == 2

    cold = mk("b")
    cold.plan(idx_a, slot)
    data_sync, (remap_sync,) = cold.plan(idx_b, slot)    # no prefetch call
    for a, b in zip(data_pre, data_sync):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(remap_pre),
                                  np.asarray(remap_sync))


def test_spilled_prefetch_mismatch_falls_back(tiny_federation, tmp_path):
    """A prefetch for the wrong schedule is discarded; plan() streams the
    actual schedule synchronously and still matches the host store."""
    xs, ys, mask = _packed_arrays(tiny_federation)
    mesh = make_mediator_mesh(1)
    store = build_client_store("spilled", xs, ys, mask, mesh, capacity=4,
                               spill_dir=str(tmp_path / "s"))
    slot = np.ones((2, 2), np.float32)
    store.prefetch(np.array([[0, 1], [2, 3]], np.int32))
    actual = np.array([[4, 5], [6, 7]], np.int32)
    data, (remap,) = store.plan(actual, slot)
    assert store.prefetch_misses == 1 and store.prefetch_hits == 0
    ref = build_client_store("host", xs, ys, mask, mesh, capacity=4)
    ref_data, (ref_remap,) = ref.plan(actual, slot)
    for a, b in zip(data, ref_data):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(remap), np.asarray(ref_remap))
    assert "spill_dir" in store.stats()
