"""TP-rows mode (§8): the ``model`` axis doing real work in engine rows.

``EngineConfig.tp_rows`` resolution contract (core/engine.py):

* ``False`` / ``model=1`` meshes / no param shardings -> gather oracle
  (replicate the model-sharded weights before the fully-manual region);
  an explicit ``True`` on a model=1 mesh also resolves off -- there is
  no model axis to split over, nothing to raise about.
* ``"auto"`` -> TP rows only on TPU/GPU backends; the XLA-CPU
  partitioner crashes on ``lax.scan`` under partial-auto shard_map, so
  CPU always falls back to the (bitwise-pinned) gather oracle.
* ``True`` on an unsupported backend -> ValueError, never a silent
  downgrade.

The 4-device subprocess mirrors tests/test_model_mesh.py (the forced
device count must precede jax initialization) and additionally pins the
2-D gather oracle WITH LoRA adapters against the 1-D trajectory.  The
true TP-vs-oracle equality check self-skips off TPU/GPU -- it is the one
leg this container cannot execute (see .github/workflows/ci.yml
``tier1-tp-rows``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import LocalSpec
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.launch.mesh import make_fl_mesh, make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam


def _cfg(**kw):
    kw.setdefault("donate_params", False)
    return EngineConfig.astraea(clients_per_round=6, gamma=3,
                                local=LocalSpec(10, 1), seed=0, **kw)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tp_rows_config_validation():
    with pytest.raises(ValueError, match="tp_rows"):
        _cfg(tp_rows="yes")
    for mode in (True, False, "auto"):
        assert _cfg(tp_rows=mode).tp_rows == mode


def test_tp_rows_resolves_off_without_model_axis(tiny_federation):
    """model=1 meshes have nothing to tensor-split: every mode -- even an
    explicit True -- resolves to the oracle, and the (1,1) 2-D trajectory
    stays bitwise the 1-D one."""
    model = emnist_cnn(8, image_size=16)

    def run(mesh, mode):
        e = FLRoundEngine(model, adam(1e-3), tiny_federation,
                          _cfg(tp_rows=mode), mesh=mesh)
        assert e._tp_rows is False
        e.run_round()
        e.run_round()
        return e

    e_true = run(make_fl_mesh(mediator=1, model=1), True)
    e_auto = run(make_fl_mesh(mediator=1, model=1), "auto")
    e_1d = run(make_mediator_mesh(1), "auto")
    _params_equal(e_true.params, e_auto.params)
    _params_equal(e_auto.params, e_1d.params)
    assert e_auto.num_round_traces == 1


_FORCED_4DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.pop("ASTRAEA_MODEL_PARALLEL", None)
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_fl_mesh, make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    assert jax.default_backend() == "cpu"
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)

    def cfg(**kw):
        return EngineConfig.astraea(clients_per_round=6, gamma=3,
                                    local=LocalSpec(10, 1), seed=0,
                                    pad_mediators_to=2, row_exec="map",
                                    donate_params=False, **kw)

    m22 = make_fl_mesh(mediator=2, model=2)

    # (a) an explicit True on the CPU backend must raise, not downgrade
    try:
        FLRoundEngine(model, adam(1e-3), fed, cfg(tp_rows=True), mesh=m22)
    except ValueError as e:
        assert "TPU/GPU" in str(e), e
    else:
        raise AssertionError("tp_rows=True on CPU did not raise")

    # (b) "auto" resolves to the gather oracle on CPU: 2x2 == 1-D bitwise
    def run(mesh, **kw):
        e = FLRoundEngine(model, adam(1e-3), fed, cfg(**kw), mesh=mesh)
        assert e._tp_rows is False
        e.run_round()
        e.run_round()
        return e

    e22 = run(m22, tp_rows="auto")
    e1d = run(make_mediator_mesh(2), tp_rows="auto")
    for x, y in zip(jax.tree.leaves(e22.params), jax.tree.leaves(e1d.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert e22.num_round_traces == 1

    # (c) gather-mode LoRA on the 2-D mesh: adapters stay bitwise the 1-D
    # run's (the backbone operand is gathered, adapters are replicated),
    # and the WAN ledger stays adapter-sized and layout-invariant
    l22 = run(m22, tp_rows="auto", lora_rank=2)
    l1d = run(make_mediator_mesh(2), tp_rows="auto", lora_rank=2)
    for x, y in zip(jax.tree.leaves(jax.device_get(l22.adapters)),
                    jax.tree.leaves(jax.device_get(l1d.adapters))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert l22.num_round_traces == 1
    assert l22.comm.total_bytes == l1d.comm.total_bytes
    assert l22.comm.wan_adapter_bytes == l22.comm.total_bytes
    assert l22.comm.intra_pod_bytes > 0      # backbone gather is charged
    print("OK")
""")


def test_tp_rows_forced_4dev(tmp_path):
    """CPU contract on a real 4-device 2x2 mesh: True raises, "auto"
    falls back to the (bitwise-pinned) gather oracle, with and without
    LoRA adapters."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _FORCED_4DEV_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout


@pytest.mark.skipif(jax.default_backend() not in ("tpu", "gpu"),
                    reason="TP rows only compile on TPU/GPU (XLA-CPU "
                           "partial-auto scan crash)")
def test_tp_rows_matches_gather_oracle(tiny_federation):
    """On a supported backend the true tensor-parallel row program must
    reproduce the gather oracle's trajectory (allclose, not bitwise: the
    TP matmuls tile differently) with the replica never materialized."""
    nd = len(jax.devices())
    if nd < 2 or nd % 2:
        pytest.skip(f"needs an even device count >= 2, got {nd}")
    model = emnist_cnn(8, image_size=16)
    mesh = make_fl_mesh(mediator=nd // 2, model=2)

    def run(mode):
        e = FLRoundEngine(model, adam(1e-3), tiny_federation,
                          _cfg(tp_rows=mode, row_exec="map",
                               pad_mediators_to=nd // 2), mesh=mesh)
        assert e._tp_rows is (mode is True)
        e.run_round()
        e.run_round()
        return e

    tp, oracle = run(True), run(False)
    assert tp.num_round_traces == 1
    for x, y in zip(jax.tree.leaves(tp.params), jax.tree.leaves(oracle.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
