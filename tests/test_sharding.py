"""Sharding-rule unit tests (AbstractMesh: no devices needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as S
from repro.launch.compat import abstract_mesh
from repro.models.layers import LogicalParam


@pytest.fixture
def mesh():
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture
def pod_mesh():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard(mesh):
    spec = S.spec_for((6144, 6144), ("embed", "heads"), mesh, S.TRAIN_RULES)
    assert spec == P("data", "model")


def test_flat_head_dim_shards_when_divisible(mesh):
    # internvl2: 14 heads but the flattened H*hd = 896 divides 16 -- the
    # weight shards across head boundaries; activation constraints on the
    # (b,s,H,hd) view fall back to UNCONSTRAINED (14 % 16 != 0)
    spec = S.spec_for((896, 896), ("embed", "heads"), mesh, S.TRAIN_RULES)
    assert spec == P("data", "model")


def test_truly_indivisible_dims_replicate(mesh):
    spec = S.spec_for((50280,), ("vocab",), mesh, S.TRAIN_RULES)
    assert spec == P()


def test_expert_fallback_to_mlp(mesh):
    # grok: 8 experts < 16 devices -> expert dim replicated, mlp sharded
    spec = S.spec_for((8, 6144, 32768), ("expert", "embed", "mlp"),
                      mesh, S.TRAIN_RULES)
    assert spec == P(None, "data", "model")


def test_pod_fsdp_uses_both_axes(pod_mesh):
    spec = S.spec_for((6144, 32768), ("embed", "mlp"), pod_mesh, S.TRAIN_RULES)
    assert spec == P(("pod", "data"), "model")


def test_axis_used_once_per_param(mesh):
    # both dims want "model": only the first gets it
    spec = S.spec_for((256, 256), ("vocab", "mlp"), mesh, S.TRAIN_RULES)
    assert spec in (P("model"), P("model", None))
    assert list(spec).count("model") == 1


def test_vocab_not_divisible_replicates(mesh):
    spec = S.spec_for((51865, 512), ("vocab", "embed"), mesh, S.TRAIN_RULES)
    assert spec == P(None, "data") or spec == P(None, None)


def test_batch_shardings(mesh):
    specs = {"tokens": jax.ShapeDtypeStruct((256, 4096), np.int32),
             "odd": jax.ShapeDtypeStruct((3, 5), np.float32)}
    sh = S.batch_shardings(specs, mesh)
    assert sh["tokens"].spec == P(("data",))
    assert sh["odd"].spec == P()


def test_cache_shardings_batch_and_kv(mesh):
    cache = {"k": jax.ShapeDtypeStruct((24, 128, 4096, 16, 128), np.float32)}
    sh = S.cache_shardings(cache, mesh)
    spec = sh["k"].spec
    assert spec[1] in (("data",), "data")   # batch axis
    assert spec[3] == "model"               # kv-head axis


def test_param_shardings_tree(mesh):
    specs = {"a": LogicalParam((1024, 512), ("embed", "mlp")),
             "b": LogicalParam((7,), ("ssm_heads",))}
    out = S.param_shardings(specs, mesh)
    assert out["a"].spec == P("data", "model")
    assert out["b"].spec == P()
