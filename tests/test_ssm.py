"""SSD (mamba2) correctness: chunked algorithm vs naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssm


def naive_ssd(x, dt, A, B, C, D):
    """Token-by-token recurrence (the definition)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Bf = np.asarray(B, np.float64)
    Cf = np.asarray(C, np.float64)
    Af = np.asarray(A, np.float64)
    for t in range(l):
        g = np.exp(dtf[:, t] * Af)                        # (b, h)
        upd = np.einsum("bh,bn,bhp->bhpn", dtf[:, t], Bf[:, t], xf[:, t])
        state = g[..., None, None] * state + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cf[:, t], state) \
            + np.asarray(D)[None, :, None] * xf[:, t]
    return ys, state


def _inputs(key, b=2, l=64, h=3, p=4, n=8):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n)) * 0.5
    C = jax.random.normal(ks[4], (b, l, n)) * 0.5
    D = jnp.ones((h,)) * 0.5
    return x, dt, A, B, C, D


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches_naive(key, chunk):
    x, dt, A, B, C, D = _inputs(key)
    y, state = ssm.ssd_chunked(x, dt, A, B, C, D, chunk)
    y_ref, state_ref = naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_chunk_size_invariance(seed):
    key = jax.random.PRNGKey(seed)
    x, dt, A, B, C, D = _inputs(key, l=32)
    y8, s8 = ssm.ssd_chunked(x, dt, A, B, C, D, 8)
    y32, s32 = ssm.ssd_chunked(x, dt, A, B, C, D, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s32), rtol=2e-4, atol=2e-4)


def test_decode_step_continues_prefill(key):
    """Prefill final state + decode step == one longer prefill."""
    x, dt, A, B, C, D = _inputs(key, l=33)
    y_full, state_full = ssm.ssd_chunked(
        x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], D, 8)
    y_last, state_last = ssm.ssd_decode_step(
        x[:, 32], dt[:, 32], A, B[:, 32], C[:, 32], D, state_full)
    y_ref, state_ref = naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y_last), y_ref[:, 32], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_last), state_ref, rtol=2e-4, atol=2e-4)


def test_causal_conv_tail_equivalence(key):
    """Streaming conv with carried tail == full-sequence conv."""
    w = jax.random.normal(key, (4, 6)) * 0.3
    b = jnp.zeros((6,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 20, 6))
    full, _ = ssm.causal_conv1d(x, w, b)
    first, tail = ssm.causal_conv1d(x[:, :12], w, b)
    second, _ = ssm.causal_conv1d(x[:, 12:], w, b, tail)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([first, second], 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-6)


def test_segsum_values():
    dA = jnp.asarray([[1.0, 2.0, 3.0]])
    S = np.asarray(ssm.segsum(dA))[0]
    assert S[0, 0] == 0.0
    assert S[1, 0] == pytest.approx(2.0)
    assert S[2, 0] == pytest.approx(5.0)
    assert S[2, 1] == pytest.approx(3.0)
    assert S[0, 1] == -np.inf
