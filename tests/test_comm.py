"""CommMeter wiring: the WAN-traffic ledger behind the paper's Table III.

The 82% communication-saving claim is a ratio of byte ledgers, so the
meter must (a) be fed by every trainer round, (b) follow the paper's
per-round formulas exactly, and (c) have its per-wave (async) accounting
sum to the per-round accounting."""
import math

import numpy as np
import pytest

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.core.comm import CommMeter
from repro.core.fedavg import FedAvgTrainer
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import count_params, emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def test_every_round_appends_cumulative_bytes(model, tiny_federation):
    """Both trainers leave one cumulative round_log entry per round, and
    the eval history's traffic_mb matches the ledger."""
    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=4, local=LocalSpec(10, 1), seed=0,
                       mesh=make_mediator_mesh(1))
    hist = fa.fit(3, eval_every=1)
    assert len(fa.comm.round_log) == 3
    assert all(b > a for a, b in zip(fa.comm.round_log, fa.comm.round_log[1:]))
    assert hist[-1]["traffic_mb"] == pytest.approx(
        fa.comm.round_log[-1] / 2 ** 20)

    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
                        alpha=None, seed=0, mesh=make_mediator_mesh(1))
    tr.run_round()
    tr.run_round()
    assert len(tr.comm.round_log) == 2
    assert tr.comm.round_log[1] == pytest.approx(2 * tr.comm.round_log[0])


def test_fedavg_vs_astraea_byte_ratio(model, tiny_federation):
    """Paper §IV-C per-round formulas, asserted through the trainers:
    FedAvg moves 2c|w| per round; Astraea 2|w|(c E_m + ceil(c/gamma)).
    The per-round byte RATIO is therefore (c E_m + ceil(c/gamma)) / c --
    Astraea pays a mediator surcharge per round and wins Table III by
    needing ~3x fewer rounds to the target accuracy."""
    c, gamma, em, rounds = 6, 3, 2, 2
    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=c, local=LocalSpec(10, 1), seed=0,
                       mesh=make_mediator_mesh(1))
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=c, gamma=gamma,
                        local=LocalSpec(10, 1), mediator_epochs=em,
                        alpha=None, seed=0, mesh=make_mediator_mesh(1))
    for _ in range(rounds):
        fa.run_round()
        tr.run_round()
    w = count_params(fa.params) * 4
    assert fa.comm.total_bytes == pytest.approx(rounds * 2 * c * w)
    expect_astraea = rounds * 2 * w * (c * em + math.ceil(c / gamma))
    assert tr.comm.total_bytes == pytest.approx(expect_astraea)
    ratio = tr.comm.total_bytes / fa.comm.total_bytes
    assert ratio == pytest.approx((c * em + math.ceil(c / gamma)) / c)


def test_per_wave_accounting_sums_to_per_round():
    """A round's waves partition its clients and mediators, so the wave
    charges must reproduce the round formula exactly."""
    whole = CommMeter(num_params=1000)
    whole.astraea_round(c=6, gamma=3, mediator_epochs=2)
    waved = CommMeter(num_params=1000)
    waved.astraea_wave(clients=4, mediators=1, mediator_epochs=2)
    waved.astraea_wave(clients=2, mediators=1, mediator_epochs=2)
    assert waved.total_bytes == whole.total_bytes

    whole = CommMeter(num_params=1000)
    whole.fedavg_round(5)
    waved = CommMeter(num_params=1000)
    waved.fedavg_wave(3)
    waved.fedavg_wave(2)
    assert waved.total_bytes == whole.total_bytes


def test_plan_broadcast_on_the_ledger(model, tiny_federation):
    """Alg. 2's one-off plan broadcast is WAN traffic: (num_classes,) int32
    down to every client, charged once at initialization in BOTH
    augmentation modes, then rounds accrue on top."""
    m = CommMeter(num_params=1000)
    m.plan_broadcast(8, 12)
    assert m.total_bytes == 8 * 4 * 12

    k = tiny_federation.num_clients
    nc = tiny_federation.num_classes
    plan_bytes = nc * 4 * k
    kw = dict(clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
              alpha=0.67, seed=0, mesh=make_mediator_mesh(1))
    for mode in ("online", "materialized"):
        tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                            aug_mode=mode, **kw)
        assert tr.comm.total_bytes == plan_bytes, mode
        tr.run_round()
        w = count_params(tr.params) * 4
        expect_round = 2 * w * (6 * 1 + math.ceil(6 / 3))
        assert tr.comm.total_bytes == pytest.approx(plan_bytes + expect_round)
    # no augmentation -> no plan, no broadcast
    off = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                         **{**kw, "alpha": None})
    assert off.comm.total_bytes == 0


def test_intra_pod_ledger_never_touches_wan():
    """Model-axis collectives (the 2-D mesh's per-round tensor-parallel
    param gather) land on the intra-pod ledger ONLY: the WAN ledger --
    the denominator of the paper's 82% claim -- must be invariant to the
    server's model-parallel layout. (The end-to-end version, 2x2 vs 4x1
    trainers on real devices, is asserted in tests/test_model_mesh.py.)"""
    m = CommMeter(num_params=1000)
    m.astraea_round(c=6, gamma=3, mediator_epochs=2)
    wan = m.total_bytes
    # 4 devices each all-gather the half of the params they do not hold
    m.model_axis_round(num_devices=4, model_size=2)
    assert m.total_bytes == wan                     # WAN untouched
    assert m.intra_pod_bytes == 4 * m.model_bytes * 0.5
    assert m.intra_pod_megabytes == pytest.approx(
        m.intra_pod_bytes / 2 ** 20)
    m.end_round()
    assert m.round_log == [wan]                     # round_log is WAN-only
    # a degenerate model axis charges nothing anywhere
    m.model_axis_round(num_devices=4, model_size=1)
    assert m.intra_pod_bytes == 4 * m.model_bytes * 0.5
    # 4-way model axis: 3/4 of the params ride the interconnect per device
    m2 = CommMeter(num_params=1000)
    m2.model_axis_round(num_devices=8, model_size=4)
    assert m2.intra_pod_bytes == 8 * m2.model_bytes * 0.75
    assert m2.total_bytes == 0


def test_store_traffic_lands_on_intra_pod_breakdown():
    """Unit: host->device streaming and the serve exchange accrue on their
    own intra-pod counters; the WAN ledger never moves."""
    m = CommMeter(num_params=1000)
    m.store_stream(100)
    m.store_exchange(60)
    m.store_stream(40)
    assert m.total_bytes == 0
    assert m.store_stream_bytes == 140 and m.store_exchange_bytes == 60
    assert m.intra_pod_bytes == 200


def test_store_streaming_charged_and_wan_invariant(model, tiny_federation):
    """End-to-end: every byte the host/spilled stores stream to device is
    charged to the intra-pod ledger (and only there); the WAN total --
    the 82% claim's denominator -- is identical under every placement
    policy, because placement is a server-side deployment detail."""
    import dataclasses
    from repro.core.engine import EngineConfig, FLRoundEngine
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=2,
                               reschedule_every_round=True)
    engines = {}
    for store in ("replicated", "sharded", "host", "spilled"):
        e = FLRoundEngine(model, adam(1e-3), tiny_federation,
                          dataclasses.replace(cfg, store=store),
                          mesh=make_mediator_mesh(1))
        e.run_round()
        e.run_round()
        engines[store] = e
    assert len({e.comm.total_bytes for e in engines.values()}) == 1
    for name in ("host", "spilled"):
        e = engines[name]
        assert e.store._streamed_bytes > 0
        assert e.comm.store_stream_bytes == e.store._streamed_bytes
        assert e.store.stats()["streamed_bytes"] == e.store._streamed_bytes
        assert e.store.stats()["num_streams"] == 2       # one per reschedule
        assert e.comm.intra_pod_bytes == e.comm.model_axis_tp_bytes + \
            e.comm.store_stream_bytes + e.comm.store_exchange_bytes
    rep = engines["replicated"].comm
    assert rep.store_stream_bytes == 0 and rep.store_exchange_bytes == 0


def test_async_trainer_traffic_matches_sync(model, tiny_federation):
    """Waves re-partition WHEN bytes move, not how many: an async run's
    ledger equals the synchronous run's after the same number of rounds."""
    from repro.core.async_engine import AsyncSpec
    from repro.core.staleness import StragglerSpec
    kw = dict(clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
              alpha=None, seed=0, mesh=make_mediator_mesh(1))
    sync = AstraeaTrainer(model, adam(1e-3), tiny_federation, **kw)
    a = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                       async_spec=AsyncSpec(
                           staleness_bound=1, wave_size=1,
                           straggler=StragglerSpec(model="fixed", seed=0)),
                       **kw)
    for _ in range(2):
        sync.run_round()
        a.run_round()
    assert a.comm.total_bytes == pytest.approx(sync.comm.total_bytes)
    assert len(a.comm.round_log) == len(sync.comm.round_log) == 2
