"""LoRA adapter-delta exchange: mapping table, merge rule, engine wiring.

The invariants pinned here are the contract of models/lora.py plus its
engine integration (core/engine.py, core/async_engine.py, core/comm.py):

* mapping construction -- factorized iff the tensor has a real matmul
  shape AND rank < min(din, dout); batch axes batch the factorization;
  rank=0 is the empty mapping.
* merge rule -- ``W + (alpha/rank) * (A @ B).reshape(W.shape)`` for
  factorized entries, bitwise pass-through for dense ones.
* full-rank == full-delta oracle, BITWISE, in both aggregation modes
  (astraea deltas and fedavg weights): at full rank every entry is dense,
  so the adapter round executes the oracle's own arithmetic.
* rank 0 == frozen backbone with zero adapter bytes on the WAN.
* exact byte accounting -- the ledger's adapter counters equal the
  closed-form ``rounds * legs * payload`` with ``==``, not isclose.
* zero re-traces across reschedules with adapters on, and one merge
  trace across repeated ``merged_params()`` calls.
* async S=0 with adapters is bitwise the sync trajectory (same ledger).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
from repro.core.comm import CommMeter
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fl import LocalSpec
from repro.models import lora
from repro.models.cnn import emnist_cnn
from repro.models.layers import LogicalParam
from repro.optim.optimizers import sgd

C, GAMMA, EM, ROUNDS = 8, 4, 1, 3
LEGS = 2 * C * EM + 2 * math.ceil(C / GAMMA)


def tree_bitwise(a, b):
    a, b = jax.device_get(a), jax.device_get(b)
    if jax.tree.structure(a) != jax.tree.structure(b):
        return False
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def make_engine(fed, mode="astraea", **kw):
    model = emnist_cnn(8, image_size=16)
    local = LocalSpec(batch_size=10, epochs=1)
    if mode == "astraea":
        cfg = EngineConfig.astraea(clients_per_round=C, gamma=GAMMA,
                                   local=local, mediator_epochs=EM,
                                   donate_params=False, seed=0, **kw)
    else:
        cfg = EngineConfig.fedavg(clients_per_round=C, local=local,
                                  donate_params=False, seed=0, **kw)
    return FLRoundEngine(model, sgd(0.05), fed, cfg)


def run_rounds(eng, n=ROUNDS):
    for _ in range(n):
        eng.run_round()
    return eng


# ---------------------------------------------------------------------------
# mapping table construction
# ---------------------------------------------------------------------------

def test_mapping_kinds_and_shapes():
    specs = emnist_cnn(8, image_size=16).param_specs()
    m = lora.build_mapping(specs, rank=2)
    conv = m["conv1/w"]
    assert conv.kind == "factorized"
    # conv (5,5,1,12): din folds every non-batch dim but the last
    assert (conv.din, conv.dout) == (25, 12)
    assert conv.a_shape == (25, 2) and conv.state_shape == (2, 12)
    assert conv.alpha == 2.0                      # default alpha = rank
    bias = m["conv1/b"]
    assert bias.kind == "dense" and bias.state_shape == bias.shape
    # every backbone tensor has exactly one entry
    assert len(m) == len(jax.tree.leaves(specs))


def test_mapping_rank_geq_min_dim_goes_dense():
    specs = {"w": LogicalParam((4, 16), ("embed", "mlp"))}
    m = lora.build_mapping(specs, rank=4)          # rank == min(4, 16)
    assert m["w"].kind == "dense"
    m = lora.build_mapping(specs, rank=3)
    assert m["w"].kind == "factorized" and m["w"].rank == 3


def test_mapping_batch_axes():
    # stacked-layer projection: the "layers" dim batches the factorization
    specs = {"proj": LogicalParam((3, 8, 6, 16),
                                  ("layers", "kh", "embed", "mlp"))}
    e = lora.build_mapping(specs, rank=2)["proj"]
    assert e.kind == "factorized"
    assert e.batch_shape == (3,) and e.batch_axes == ("layers",)
    assert (e.din, e.dout) == (48, 16)
    assert e.a_shape == (3, 48, 2) and e.state_shape == (3, 2, 16)


def test_rank0_empty_mapping():
    specs = emnist_cnn(8, image_size=16).param_specs()
    assert lora.build_mapping(specs, rank=0) == {}
    assert lora.exchange_nbytes({}) == 0
    with pytest.raises(ValueError):
        lora.build_mapping(specs, rank=-1)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 32), st.integers(2, 32), st.integers(1, 40))
def test_mapping_cost_property(din, dout, rank):
    """Factorized iff rank < min(din, dout); either way the exchanged
    state never costs more than the dense tensor it adapts."""
    specs = {"w": LogicalParam((din, dout), ("embed", "mlp"))}
    e = lora.build_mapping(specs, rank=rank)["w"]
    if rank < min(din, dout):
        assert e.kind == "factorized"
        assert e.state_params == rank * dout
    else:
        assert e.kind == "dense"
        assert e.state_params == din * dout
    assert e.state_params <= din * dout
    assert lora.exchange_nbytes({"w": e}) == e.state_params * 4
    fr = lora.full_rank(specs)
    assert lora.build_mapping(specs, fr)["w"].kind == "dense"


def test_merge_rule_matches_manual_math():
    key = jax.random.PRNGKey(7)
    backbone = {"w": jax.random.normal(key, (6, 10)),
                "b": jax.random.normal(jax.random.fold_in(key, 1), (10,))}
    specs = {"w": LogicalParam((6, 10), ("embed", "mlp")),
             "b": LogicalParam((10,), ("mlp",))}
    m = lora.build_mapping(specs, rank=2, alpha=5.0)
    a = lora.init_adapter_A(jax.random.fold_in(key, lora.A_SALT), m)
    state = lora.init_adapter_state(m, backbone)
    # zero-init B: merge is the identity (bitwise for dense, exact-add 0)
    merged0 = lora.merge_params(backbone, a, state, m)
    assert np.array_equal(np.asarray(merged0["b"]), np.asarray(backbone["b"]))
    np.testing.assert_array_equal(np.asarray(merged0["w"]),
                                  np.asarray(backbone["w"]))
    state = {"w": jax.random.normal(jax.random.fold_in(key, 2), (2, 10)),
             "b": state["b"] + 1.0}
    merged = lora.merge_params(backbone, a, state, m)
    want = backbone["w"] + (5.0 / 2.0) * (a["w"] @ state["w"])
    np.testing.assert_allclose(np.asarray(merged["w"]), np.asarray(want),
                               rtol=1e-6)
    # dense entries pass through bitwise
    assert np.array_equal(np.asarray(merged["b"]), np.asarray(state["b"]))


def test_frozen_a_is_seed_deterministic():
    specs = emnist_cnn(8, image_size=16).param_specs()
    m = lora.build_mapping(specs, rank=2)
    k = jax.random.fold_in(jax.random.PRNGKey(3), lora.A_SALT)
    assert tree_bitwise(lora.init_adapter_A(k, m), lora.init_adapter_A(k, m))
    # per-path keys: entries differ from each other
    a = lora.init_adapter_A(k, m)
    paths = [p for p, e in m.items() if e.kind == "factorized"]
    assert len(paths) >= 2
    s0, s1 = a[paths[0]].ravel()[:4], a[paths[1]].ravel()[:4]
    assert not np.array_equal(np.asarray(s0), np.asarray(s1))


# ---------------------------------------------------------------------------
# engine integration: rank sweep against the full-delta oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def oracle(tiny_federation):
    return run_rounds(make_engine(tiny_federation))


def test_full_rank_bitwise_equals_oracle(tiny_federation, oracle):
    fr = lora.full_rank(emnist_cnn(8, image_size=16).param_specs())
    eng = run_rounds(make_engine(tiny_federation, lora_rank=fr))
    assert tree_bitwise(eng.merged_params(), oracle.params)
    assert eng.comm.adapter_reduction_ratio == 1.0


def test_rank0_frozen_backbone_zero_bytes(tiny_federation):
    eng = run_rounds(make_engine(tiny_federation, lora_rank=0), n=2)
    assert eng.adapters == {}
    assert tree_bitwise(eng.merged_params(), eng.params)
    assert eng.comm.wan_adapter_bytes == 0
    assert eng.comm.total_bytes == 0
    # the counterfactual still accrues, so the ratio is a true 0
    assert eng.comm.adapter_reduction_ratio == 0.0


def test_fedavg_weights_mode_full_rank_bitwise(tiny_federation):
    f0 = run_rounds(make_engine(tiny_federation, mode="fedavg"), n=2)
    fr = lora.full_rank(emnist_cnn(8, image_size=16).param_specs())
    f1 = run_rounds(make_engine(tiny_federation, mode="fedavg",
                                lora_rank=fr), n=2)
    assert tree_bitwise(f1.merged_params(), f0.params)


def test_rank2_reduces_wan_bytes(tiny_federation, oracle):
    eng = run_rounds(make_engine(tiny_federation, lora_rank=2))
    ratio = eng.comm.adapter_reduction_ratio
    assert ratio is not None and ratio <= 0.10
    assert eng.comm.total_bytes < oracle.comm.total_bytes
    # full-size counterfactual of the adapter legs == the oracle's ledger
    assert eng.comm.wan_adapter_full_equiv_bytes == oracle.comm.total_bytes


def test_exact_ledger_accounting(tiny_federation):
    eng = run_rounds(make_engine(tiny_federation, lora_rank=2))
    payload = lora.exchange_nbytes(eng._lora_mapping)
    assert eng.comm.adapter_payload_bytes == payload
    assert eng.comm.wan_adapter_bytes == ROUNDS * LEGS * payload
    assert eng.comm.wan_adapter_full_equiv_bytes == \
        ROUNDS * LEGS * eng.comm.model_bytes
    assert eng.comm.total_bytes == eng.comm.wan_adapter_bytes
    assert eng.comm.wan_full_delta_bytes == 0


def test_zero_retrace_across_reschedules(tiny_federation):
    eng = make_engine(tiny_federation, lora_rank=2,
                      reschedule_every_round=True)
    run_rounds(eng)
    assert eng.num_round_traces == 1
    eng.merged_params()
    eng.run_round()
    eng.merged_params()
    assert eng.num_round_traces == 1
    assert eng.num_merge_traces == 1


def test_async_s0_bitwise_equals_sync(tiny_federation):
    sync = run_rounds(make_engine(tiny_federation, lora_rank=2))
    eng = make_engine(tiny_federation, lora_rank=2)
    a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=0, wave_size=1))
    for _ in range(ROUNDS):
        a.run_round()
    assert tree_bitwise(eng.adapters, sync.adapters)
    assert tree_bitwise(eng.merged_params(), sync.merged_params())
    assert eng.comm.total_bytes == sync.comm.total_bytes
    assert eng.comm.wan_adapter_bytes == sync.comm.wan_adapter_bytes


def test_kernel_agg_on_adapter_trees(tiny_federation):
    ref = make_engine(tiny_federation, lora_rank=2)
    ref.run_round()
    eng = make_engine(tiny_federation, lora_rank=2, use_kernel_agg=True)
    eng.run_round()
    for x, y in zip(jax.tree.leaves(jax.device_get(ref.adapters)),
                    jax.tree.leaves(jax.device_get(eng.adapters))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
    # rank 0: the fused path must accept the EMPTY adapter tree
    e0 = make_engine(tiny_federation, lora_rank=0, use_kernel_agg=True)
    e0.run_round()
    assert e0.adapters == {}


# ---------------------------------------------------------------------------
# comm meter: the WAN split in isolation
# ---------------------------------------------------------------------------

def test_comm_meter_adapter_split():
    m = CommMeter(num_params=1000)                # 4000-byte legs
    m.fedavg_round(3)
    assert m.wan_full_delta_bytes == 6 * 4000
    assert m.adapter_reduction_ratio is None
    m.adapter_payload_bytes = 400
    m.astraea_round(C, GAMMA, EM)
    assert m.wan_adapter_bytes == LEGS * 400
    assert m.wan_adapter_full_equiv_bytes == LEGS * 4000
    assert m.adapter_reduction_ratio == 0.1
    assert m.total_bytes == 6 * 4000 + LEGS * 400
    totals = m.ledger_totals()
    assert totals["wan_adapter_bytes_total"] == m.wan_adapter_bytes
    assert totals["wan_full_delta_bytes_total"] == m.wan_full_delta_bytes
    assert totals["wan_adapter_full_equiv_bytes_total"] == \
        m.wan_adapter_full_equiv_bytes
