"""Online rebalancing inside the round program (the PR-4 data-flow flip).

Contract under test (core/engine.py §7 + core/augmentation.py):

* stores keep the RAW federation -- per-device bytes equal the
  no-augmentation pack under every placement policy;
* all three stores produce bitwise-identical trajectories with the
  in-round resample+warp enabled, and the round executable still compiles
  exactly once (``num_round_traces == 1``), including across async waves;
* Alg. 3 schedules on the expected post-augmentation histograms and Eq. 6
  weighs mediators by expected post-augmentation sizes;
* the trainer API: ``aug_mode`` selects online / materialized / none.

The 4-device subprocess mirrors tests/test_client_store.py: device count
must be forced before jax initializes.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import LocalSpec, augmentation
from repro.core.astraea import AstraeaTrainer
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fedavg import FedAvgTrainer
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam

STORES = ("replicated", "sharded", "host")


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _trainer(model, fed, store="replicated", **kw):
    kw.setdefault("alpha", 0.67)
    return AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=6,
                          gamma=3, local=LocalSpec(10, 1), seed=0,
                          store=store, mesh=make_mediator_mesh(1),
                          reschedule_every_round=True, **kw)


def test_online_store_bytes_stay_raw(model, tiny_federation):
    """The headline of the data-flow inversion: with online augmentation
    the per-device client-store bytes equal the raw pre-augmentation pack
    under all three placement policies; materializing inflates them."""
    for store in STORES:
        on = _trainer(model, tiny_federation, store)
        raw = _trainer(model, tiny_federation, store, alpha=None)
        assert on.engine.store.per_device_bytes() == \
            raw.engine.store.per_device_bytes(), store
        assert on.engine.store.stats()["policy"] == store
    mat = _trainer(model, tiny_federation, aug_mode="materialized")
    rawb = _trainer(model, tiny_federation, alpha=None
                    ).engine.store.per_device_bytes()
    assert mat.engine.store.per_device_bytes() > rawb
    assert mat.extra_storage_frac > 0
    # the online trainer reports the avoided cost and realizes none of it
    on = _trainer(model, tiny_federation)
    assert on.extra_storage_frac == 0.0
    assert on.planned_extra_frac == pytest.approx(mat.extra_storage_frac)


def test_online_stores_bitwise_identical_single_trace(model, tiny_federation):
    """sharded + host == replicated bitwise with the in-round warp on, and
    per-round reschedules never re-trace the augmented round executable."""
    runs = {}
    for store in STORES:
        tr = _trainer(model, tiny_federation, store)
        tr.run_round()
        tr.run_round()
        runs[store] = tr
        assert tr.engine.num_round_traces == 1, store
        assert tr.engine.num_schedule_packs == 2
    for store in ("sharded", "host"):
        _params_equal(runs["replicated"].params, runs[store].params)


def test_online_differs_from_no_aug(model, tiny_federation):
    """The in-round warp must actually change training (guards against the
    hook silently not running)."""
    on = _trainer(model, tiny_federation)
    off = _trainer(model, tiny_federation, alpha=None)
    on.run_round()
    off.run_round()
    same = all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(
        jax.tree.leaves(on.params), jax.tree.leaves(off.params)))
    assert not same


def test_online_schedule_uses_expected_counts(model, tiny_federation):
    """Alg. 3 packs mediators by the histograms clients will actually train
    on: raw counts scaled by (1 + plan)."""
    tr = _trainer(model, tiny_federation)
    plan = tr.augmentation_plan
    raw = tiny_federation.client_counts()
    np.testing.assert_allclose(tr.engine._counts, raw * (1.0 + plan))
    # and the engine refuses a plan that does not match the class count
    with pytest.raises(ValueError, match="aug_plan shape"):
        FLRoundEngine(model, adam(1e-3), tiny_federation,
                      EngineConfig.astraea(clients_per_round=6, gamma=3,
                                           local=LocalSpec(10, 1)),
                      mesh=make_mediator_mesh(1),
                      aug_plan=np.zeros(3, np.int64))


def test_zero_plan_disables_engine_hook(model, tiny_federation):
    """A perfectly balanced federation yields an all-zero plan: there is
    nothing to augment, so online mode must NOT install the in-round
    resample (which would bootstrap-resample every batch and pay a warp it
    discards) -- the trajectory stays bitwise-identical to alpha=None."""
    from repro.data.federated import FederatedDataset
    rng = np.random.default_rng(0)
    nc = tiny_federation.num_classes
    imgs = [rng.normal(size=(nc * 4, 16, 16, 1)).astype(np.float32)
            for _ in range(6)]
    labels = [np.tile(np.arange(nc), 4).astype(np.int64) for _ in range(6)]
    fed = FederatedDataset(imgs, labels, tiny_federation.test_images,
                           tiny_federation.test_labels, nc, "balanced")
    kw = dict(clients_per_round=4, gamma=2, local=LocalSpec(8, 1), seed=0,
              mesh=make_mediator_mesh(1))
    on = AstraeaTrainer(model, adam(1e-3), fed, alpha=0.67, **kw)
    assert on.augmentation_plan is not None
    assert np.all(on.augmentation_plan == 0)
    assert on.engine._aug_plan is None          # hook not installed
    off = AstraeaTrainer(model, adam(1e-3), fed, alpha=None, **kw)
    on.run_round()
    off.run_round()
    _params_equal(on.params, off.params)
    assert on.comm.total_bytes == off.comm.total_bytes  # no plan broadcast


def test_online_async_s0_bitwise_and_single_trace(model, tiny_federation):
    """S=0 async == synchronous engine bitwise WITH augmentation enabled
    (aug keys ride the round keys, not wave membership), still one trace."""
    from repro.core.async_engine import AsyncSpec
    from repro.core.staleness import StragglerSpec
    sync = _trainer(model, tiny_federation)
    asy = _trainer(model, tiny_federation,
                   async_spec=AsyncSpec(
                       staleness_bound=0, wave_size=1,
                       straggler=StragglerSpec(model="fixed", seed=0)))
    for _ in range(2):
        sync.run_round()
        asy.run_round()
    _params_equal(sync.params, asy.params)
    assert asy.engine.num_round_traces == 1


def test_trainer_aug_mode_api(model, tiny_federation):
    """aug_mode plumbing + the dataclasses.replace dataset rebuild."""
    with pytest.raises(ValueError, match="aug_mode"):
        _trainer(model, tiny_federation, aug_mode="lazy")
    # alpha=None disables augmentation regardless of aug_mode
    off = _trainer(model, tiny_federation, alpha=None, aug_mode="online")
    assert off.augmentation_plan is None
    assert off.engine._aug_plan is None
    on = _trainer(model, tiny_federation)
    assert on.engine._aug_plan is not None
    assert on.augmentation_plan.shape == (tiny_federation.num_classes,)
    # the materialized rebuild preserves every non-client field (the old
    # positional construction broke as soon as FederatedDataset grew one)
    mat = _trainer(model, tiny_federation, aug_mode="materialized")
    assert mat.data.name == tiny_federation.name
    assert mat.data.num_classes == tiny_federation.num_classes
    np.testing.assert_array_equal(mat.data.test_images,
                                  tiny_federation.test_images)
    np.testing.assert_array_equal(mat.data.test_labels,
                                  tiny_federation.test_labels)
    assert mat.engine._aug_plan is None         # oracle mode: host phase


def test_fedavg_online_aug(model, tiny_federation):
    """The aug-only ablation through FedAvgTrainer: plan wired, store raw,
    single trace over per-round random reschedules."""
    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=4, local=LocalSpec(10, 1),
                       alpha=0.67, seed=0, mesh=make_mediator_mesh(1))
    raw = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=4, local=LocalSpec(10, 1),
                        seed=0, mesh=make_mediator_mesh(1))
    assert fa.engine._aug_plan is not None
    assert fa.engine.store.per_device_bytes() == \
        raw.engine.store.per_device_bytes()
    fa.run_round()
    fa.run_round()
    assert fa.engine.num_round_traces == 1
    with pytest.raises(ValueError, match="aug_mode"):
        FedAvgTrainer(model, adam(1e-3), tiny_federation, clients_per_round=4,
                      local=LocalSpec(10, 1), alpha=0.5, aug_mode="eager")


def test_adaptive_plan_refreshes_per_reschedule(model, tiny_federation):
    """Per-round adaptive rebalancing (PR-4 follow-up): the Alg. 2 plan is
    recomputed from the selected cohort's label histograms at every
    reschedule, re-broadcast to the cohort (metered), handed to the
    round as an operand -- and the one compiled executable is reused."""
    c = 6
    tr = _trainer(model, tiny_federation, adaptive_plan=True)
    eng = tr.engine
    k = tiny_federation.num_clients
    nc = tiny_federation.num_classes
    # init: the global plan broadcast to every client
    assert eng.comm.total_bytes == nc * 4 * k
    plans = []
    for r in range(3):
        tr.run_round()
        assert eng.last_plan is not None and eng.last_plan.shape == (nc,)
        plans.append(eng.last_plan.copy())
        # Alg. 3 packs by the cohort plan's expected post-aug histograms
        np.testing.assert_allclose(
            eng._counts, tiny_federation.client_counts()
            * (1.0 + eng.last_plan.astype(np.float64)))
        # each reschedule re-broadcast the plan to its c-client cohort,
        # on top of the §IV-C per-round model legs
        from repro.models.cnn import count_params
        w = count_params(tr.params) * 4
        round_bytes = 2 * w * (c * 1 + -(-c // 3))      # E_m=1, gamma=3
        assert eng.comm.total_bytes == pytest.approx(
            nc * 4 * (k + (r + 1) * c) + (r + 1) * round_bytes)
    # the cohorts differ, so at least one refreshed plan must differ from
    # the initial global plan (seeded selection; holds for this federation)
    assert any(not np.array_equal(p, tr.augmentation_plan) for p in plans)
    # operand swap, not re-trace: still exactly one compiled round
    assert eng.num_round_traces == 1
    assert eng.num_schedule_packs == 3


def test_adaptive_plan_changes_training_vs_static(model, tiny_federation):
    """The refreshed cohort plans must actually reach the in-round hook:
    an adaptive run diverges from the static-plan run once a cohort's
    histogram differs from the global one."""
    static = _trainer(model, tiny_federation)
    adapt = _trainer(model, tiny_federation, adaptive_plan=True)
    diverged = False
    for _ in range(3):
        static.run_round()
        adapt.run_round()
        if not np.array_equal(adapt.engine.last_plan,
                              static.augmentation_plan):
            diverged = True
    assert diverged
    same = all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(
        jax.tree.leaves(static.params), jax.tree.leaves(adapt.params)))
    assert not same


def test_adaptive_plan_validation(model, tiny_federation):
    """adaptive_plan needs the online pipeline (alpha set, online mode);
    the engine refuses adaptivity without an installed hook."""
    with pytest.raises(ValueError, match="adaptive_plan"):
        _trainer(model, tiny_federation, adaptive_plan=True, alpha=None)
    with pytest.raises(ValueError, match="adaptive_plan"):
        _trainer(model, tiny_federation, adaptive_plan=True,
                 aug_mode="materialized")
    with pytest.raises(ValueError, match="adaptive_aug_alpha"):
        FLRoundEngine(model, adam(1e-3), tiny_federation,
                      EngineConfig.astraea(clients_per_round=6, gamma=3,
                                           local=LocalSpec(10, 1)),
                      mesh=make_mediator_mesh(1), adaptive_aug_alpha=0.67)


def test_adaptive_plan_installs_hook_on_balanced_data(model, tiny_federation):
    """A balanced federation yields an all-zero initial plan; adaptive mode
    must still install the in-round hook (a later cohort may drift),
    unlike the static zero-plan fast path."""
    from repro.data.federated import FederatedDataset
    rng = np.random.default_rng(0)
    nc = tiny_federation.num_classes
    imgs = [rng.normal(size=(nc * 4, 16, 16, 1)).astype(np.float32)
            for _ in range(6)]
    labels = [np.tile(np.arange(nc), 4).astype(np.int64) for _ in range(6)]
    fed = FederatedDataset(imgs, labels, tiny_federation.test_images,
                           tiny_federation.test_labels, nc, "balanced")
    kw = dict(clients_per_round=4, gamma=2, local=LocalSpec(8, 1), seed=0,
              mesh=make_mediator_mesh(1))
    tr = AstraeaTrainer(model, adam(1e-3), fed, alpha=0.67,
                        adaptive_plan=True, **kw)
    assert np.all(tr.augmentation_plan == 0)
    assert tr.engine._aug_plan is not None      # hook installed anyway
    tr.run_round()
    assert tr.engine.num_round_traces == 1


def test_eq6_weights_are_expected_post_aug_sizes(model, tiny_federation):
    """With the plan on, a mediator's Eq. 6 weight becomes
    sum(mask * (1 + plan[y])) over its clients -- the *expected
    post-augmentation* size, exactly sum_c counts_kc (1 + plan_c).  The
    replicated store's plan args expose the (M_pad, gamma) gather ids, so
    the expectation is reconstructible host-side."""
    tr = _trainer(model, tiny_federation)
    eng = tr.engine
    data_args, plan_args, unperm, slot, row_to_group, m_real = \
        eng.ensure_schedule()
    keys = eng._round_keys(row_to_group, m_real)
    _, weights = eng.wave_fn(eng.params, data_args, plan_args, unperm, slot,
                             keys, *eng.aug_args())
    weights = np.asarray(weights)
    idx = np.asarray(plan_args[0])              # replicated store gather ids
    slot_np = np.asarray(slot)
    plan = tr.augmentation_plan
    per_client = (tiny_federation.client_counts() * (1.0 + plan)).sum(axis=1)
    expect = (slot_np * per_client[idx]).sum(axis=1)
    np.testing.assert_allclose(weights, expect, rtol=1e-5)
    assert np.all(weights[np.asarray(row_to_group) < 0] == 0)  # dummy rows


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.astraea import AstraeaTrainer
    from repro.core.async_engine import AsyncSpec
    from repro.core.staleness import StragglerSpec
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)

    def run(store, alpha=0.67, async_spec=None):
        tr = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=6,
                            gamma=3, local=LocalSpec(10, 1), alpha=alpha,
                            seed=0, store=store, pad_mediators_to=4,
                            reschedule_every_round=True,
                            async_spec=async_spec,
                            mesh=make_mediator_mesh(4))
        tr.run_round()
        tr.run_round()
        return tr

    def check(a, b):
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # (1) 4-device mesh: all three stores bitwise identical with the
    # in-round resample+warp enabled
    r4, s4, h4 = run("replicated"), run("sharded"), run("host")
    check(s4, r4)
    check(h4, r4)

    # (2) one trace each, augmentation on, across per-round reschedules
    for tr in (r4, s4, h4):
        assert tr.engine.num_round_traces == 1, tr.engine.num_round_traces
        assert tr.engine.num_schedule_packs == 2

    # (3) per-device store bytes equal the raw pack (no aug) per policy
    for store, tr in (("replicated", r4), ("sharded", s4), ("host", h4)):
        raw = run(store, alpha=None)
        assert tr.engine.store.per_device_bytes() == \\
            raw.engine.store.per_device_bytes(), store

    # (4) async waves on the 4-device mesh: S=0 == sync bitwise with aug,
    # still one trace
    a4 = run("replicated", async_spec=AsyncSpec(
        staleness_bound=0, wave_size=1,
        straggler=StragglerSpec(model="fixed", seed=0)))
    check(a4, r4)
    assert a4.engine.num_round_traces == 1
    print("OK")
""")


def test_online_aug_multi_device(tmp_path):
    """The acceptance claims on a real 4-device mesh (subprocess: the
    device count must be forced before jax initializes)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
