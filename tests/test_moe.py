"""MoE routing invariants + layer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe


@given(g=st.sampled_from([32, 64]), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3), seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_route_topk_invariants(g, e, k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (g, e))
    cap = moe.moe_capacity(g, k, e, 1.25)
    dispatch, combine, aux = moe.route_topk(logits, k, cap)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # each (expert, slot) holds at most one token
    assert d.sum(axis=0).max() <= 1.0 + 1e-6
    # each token occupies at most k slots
    assert d.sum(axis=(1, 2)).max() <= k + 1e-6
    # combine weights are a sub-distribution per token
    assert c.sum(axis=(1, 2)).max() <= 1.0 + 1e-5
    assert np.all(c >= -1e-9)
    assert np.isfinite(float(aux))


def test_uniform_router_aux_is_one():
    """Perfectly balanced routing drives the Switch aux loss to ~1."""
    g, e = 512, 8
    logits = jnp.zeros((g, e)) + jax.random.normal(jax.random.PRNGKey(0), (g, e)) * 1e-4
    cap = moe.moe_capacity(g, 2, e)
    _, _, aux = moe.route_topk(logits, 2, cap)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_moe_glu_matches_dense_expert_when_identical():
    """If all experts share weights and capacity is ample, MoE == dense GLU."""
    key = jax.random.PRNGKey(1)
    b, s, d, f, e = 2, 32, 16, 32, 4
    x = jax.random.normal(key, (b, s, d)) * 0.3
    router = jax.random.normal(jax.random.fold_in(key, 1), (d, e))
    wg1 = jax.random.normal(jax.random.fold_in(key, 2), (d, f)) * 0.2
    wu1 = jax.random.normal(jax.random.fold_in(key, 3), (d, f)) * 0.2
    wd1 = jax.random.normal(jax.random.fold_in(key, 4), (f, d)) * 0.2
    wg = jnp.broadcast_to(wg1, (e, d, f))
    wu = jnp.broadcast_to(wu1, (e, d, f))
    wd = jnp.broadcast_to(wd1, (e, f, d))
    y, aux = moe.moe_glu(x, router, wg, wu, wd, top_k=1, group_size=32,
                         capacity_factor=float(e))  # no drops possible
    from repro.models.layers import glu_mlp
    y_ref = glu_mlp(x, wg1, wu1, wd1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_moe_glu_capacity_drops_are_bounded():
    key = jax.random.PRNGKey(2)
    b, s, d, f, e = 1, 64, 8, 16, 4
    x = jax.random.normal(key, (b, s, d))
    x = x.at[..., 0].set(1.0)                       # constant positive feature
    router = jnp.zeros((d, e)).at[0, 0].set(100.0)  # all tokens want expert 0
    wg = jnp.ones((e, d, f)) * 0.1
    wu = jnp.ones((e, d, f)) * 0.1
    wd = jnp.ones((e, f, d)) * 0.1
    y, aux = moe.moe_glu(x, router, wg, wu, wd, top_k=1, group_size=64)
    # capacity = 64*1*1.25/4 = 20 tokens survive; rest dropped (zeros)
    nonzero_rows = np.abs(np.asarray(y)).sum(-1) > 1e-9
    cap = moe.moe_capacity(64, 1, 4)
    assert nonzero_rows.sum() <= cap
    assert float(aux) > 1.0   # imbalanced routing penalized
