"""Integration test: the on-mesh Astraea round (shard_map) vs explicit
sequential-SGD + weighted-average reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.compat import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_shardings, TRAIN_RULES
from repro.launch.steps import make_fl_round
from repro.models import transformer as T
from jax.sharding import PartitionSpec as P


@pytest.fixture(scope="module")
def setup():
    cfg = C.reduced(C.get("gemma-2b"))
    cfg = dataclasses.replace(cfg, remat=False)
    mesh = make_host_mesh()
    specs = T.param_specs(cfg, max_seq=32)
    spec_tree = jax.tree.map(lambda _: P(), specs,
                             is_leaf=lambda x: hasattr(x, "axes"))
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    return cfg, mesh, spec_tree, params


def _reference_round(cfg, params, tokens, labels, lr, local_steps):
    """Sequential SGD over microbatches (one mediator), Eq. 6 with a single
    mediator == the delta itself."""
    micro = tokens.shape[0] // local_steps
    w = params
    for i in range(local_steps):
        mt = tokens[i * micro:(i + 1) * micro]
        ml = labels[i * micro:(i + 1) * micro]

        def loss_fn(p):
            return T.forward_train(p, cfg, {"tokens": mt, "labels": ml})[0]

        g = jax.grad(loss_fn)(w)
        w = jax.tree.map(lambda a, b: (a - lr * b).astype(a.dtype), w, g)
    return w


def test_fl_round_matches_sequential_reference(setup):
    cfg, mesh, spec_tree, params = setup
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    weights = jnp.full((4,), 32.0)

    fl_round = make_fl_round(cfg, mesh, spec_tree, learning_rate=0.01,
                             local_steps=4, mediator_epochs=1)
    with use_mesh(mesh):
        out = jax.jit(fl_round)(params, tokens, labels, weights)
    expect = _reference_round(cfg, params, tokens, labels, 0.01, 4)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)


def test_fl_round_mediator_epochs(setup):
    """E_m=2 must equal running the client stream twice sequentially."""
    cfg, mesh, spec_tree, params = setup
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    weights = jnp.full((2,), 32.0)
    fl2 = make_fl_round(cfg, mesh, spec_tree, learning_rate=0.01,
                        local_steps=2, mediator_epochs=2)
    with use_mesh(mesh):
        out = jax.jit(fl2)(params, tokens, labels, weights)
    w = _reference_round(cfg, params, tokens, labels, 0.01, 2)
    w = _reference_round(cfg, w, tokens, labels, 0.01, 2)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(w)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=3e-2, atol=3e-2)
