"""Data pipeline property tests (partitioners + synthetic generator)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.federated import (partition, letter_frequency_probs,
                                  normal_pdf_probs, instagram_sizes, table1)
from repro.data.synthetic import SyntheticSpec, SyntheticTask

SPEC = SyntheticSpec(num_classes=6, image_size=12)


@given(st.integers(2, 47))
@settings(max_examples=20, deadline=None)
def test_letterfreq_probs_valid(c):
    p = letter_frequency_probs(c)
    assert p.shape == (c,)
    assert p.sum() == pytest.approx(1.0)
    assert np.all(np.diff(p) <= 1e-12)       # sorted descending
    if c >= 10:
        assert p[0] / p[-1] > 2              # genuinely imbalanced


@given(st.integers(3, 20))
@settings(max_examples=10, deadline=None)
def test_normal_probs_valid(c):
    p = normal_pdf_probs(c)
    assert p.sum() == pytest.approx(1.0)
    assert p[c // 2] >= p[0]                 # peaked in the middle


def test_instagram_sizes_heavy_tailed():
    rng = np.random.default_rng(0)
    w = instagram_sizes(200, rng)
    assert w.sum() == pytest.approx(1.0)
    assert w.max() / np.median(w) > 3        # heavy tail


@pytest.mark.parametrize("global_dist", ["balanced", "letterfreq", "normal"])
def test_partition_totals_and_test_balance(global_dist):
    fed = partition(SPEC, num_clients=8, total_samples=400, test_samples=120,
                    sizes="instagram", global_dist=global_dist, local="random",
                    seed=0)
    counts = fed.client_counts()
    assert counts.shape == (8, SPEC.num_classes)
    assert abs(counts.sum() - 400) / 400 < 0.2
    # balanced test set (paper invariant)
    tc = np.bincount(fed.test_labels, minlength=SPEC.num_classes)
    assert tc.min() == tc.max()


def test_global_distribution_respected():
    fed = partition(SPEC, num_clients=20, total_samples=3000, test_samples=60,
                    sizes="even", global_dist="letterfreq", local="matched", seed=1)
    emp = fed.client_counts().sum(0)
    emp = emp / emp.sum()
    expect = letter_frequency_probs(SPEC.num_classes)
    assert np.abs(emp - expect).max() < 0.06


def test_no_identical_samples_across_clients():
    fed = partition(SPEC, num_clients=4, total_samples=200, test_samples=30,
                    seed=2)
    hashes = set()
    for x in fed.client_images:
        for img in x:
            h = img.tobytes()
            assert h not in hashes            # paper: no shared samples
            hashes.add(h)


def test_padded_rejects_truncating_pad_to():
    """pad_to smaller than the largest client must raise, not silently drop
    samples (the old behavior truncated the tail without warning)."""
    fed = partition(SPEC, num_clients=6, total_samples=300, test_samples=30,
                    sizes="instagram", seed=4)
    largest = max(x.shape[0] for x in fed.client_images)
    with pytest.raises(ValueError, match="truncate"):
        fed.padded(largest - 1)
    xs, ys, mask = fed.padded(largest)           # exact fit is fine
    assert xs.shape[1] == largest
    assert mask.sum() == sum(x.shape[0] for x in fed.client_images)


def test_synthetic_task_learnable_structure():
    """Same-class samples are closer to their prototype than to others."""
    task = SyntheticTask(SPEC, seed=3)
    rng = np.random.default_rng(3)
    ok = 0
    for c in range(SPEC.num_classes):
        s = task.sample(c, 8, rng)
        d_own = np.abs(s - task.prototypes[c]).mean()
        d_other = np.mean([np.abs(s - task.prototypes[o]).mean()
                           for o in range(SPEC.num_classes) if o != c])
        ok += d_own < d_other
    assert ok >= SPEC.num_classes - 1


def test_table1_settings_structure():
    feds = table1(SPEC, num_clients=6, total_samples=300, test_samples=60)
    assert set(feds) == {"BAL1", "BAL2", "INS", "LTRF1", "LTRF2"}
    n1 = sum(len(y) for y in feds["LTRF1"].client_labels)
    n2 = sum(len(y) for y in feds["LTRF2"].client_labels)
    assert 1.7 < n2 / n1 < 2.3               # LTRF2 has ~2x data
    sizes_ins = [len(y) for y in feds["INS"].client_labels]
    sizes_bal = [len(y) for y in feds["BAL1"].client_labels]
    assert np.std(sizes_ins) > np.std(sizes_bal)
