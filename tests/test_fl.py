"""FL machinery: client updates, FedAvg aggregation, mediator semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fl
from repro.core.fl import LocalSpec, make_client_update, weighted_average
from repro.core.mediator import make_mediator_update
from repro.models.cnn import emnist_cnn, count_params
from repro.optim import adam, sgd


@pytest.fixture(scope="module")
def small_model():
    return emnist_cnn(num_classes=5, image_size=16)


def _client_data(key, n, model, cls=0):
    x = jax.random.normal(key, (n, 16, 16, 1))
    y = jnp.full((n,), cls, jnp.int32)
    mask = jnp.ones((n,), jnp.float32)
    return x, y, mask


def test_zero_mask_client_is_noop(small_model, key):
    """Padding clients must not move the weights (mediator gamma padding)."""
    params = small_model.init(key)
    upd = make_client_update(small_model, adam(1e-3), LocalSpec(4, 2))
    x, y, _ = _client_data(key, 8, small_model)
    mask = jnp.zeros((8,), jnp.float32)
    new = upd(params, x, y, mask, key)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_client_update_reduces_loss(small_model, key):
    params = small_model.init(key)
    upd = jax.jit(make_client_update(small_model, adam(1e-3), LocalSpec(4, 3)))
    x, y, mask = _client_data(key, 16, small_model, cls=2)
    from repro.models.cnn import cross_entropy_loss
    before = float(cross_entropy_loss(small_model.apply(params, x), y))
    new = upd(params, x, y, mask, key)
    after = float(cross_entropy_loss(small_model.apply(new, x), y))
    assert after < before


def test_weighted_average_exact():
    trees = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])}
    weights = jnp.asarray([1.0, 1.0, 2.0])
    avg = weighted_average(trees, weights)
    np.testing.assert_allclose(np.asarray(avg["w"]),
                               [(1 + 3 + 10) / 4, (2 + 4 + 12) / 4])


def test_weighted_average_ignores_zero_weight():
    trees = {"w": jnp.asarray([[1.0], [100.0]])}
    avg = weighted_average(trees, jnp.asarray([1.0, 0.0]))
    np.testing.assert_allclose(np.asarray(avg["w"]), [1.0])


def test_mediator_sequential_vs_parallel(small_model, key):
    """Mediator (sequential clients) != FedAvg (parallel) -- and the mediator
    delta equals running the clients one after another by hand."""
    params = small_model.init(key)
    spec = LocalSpec(4, 1)
    med_upd = jax.jit(make_mediator_update(small_model, sgd(0.05), spec,
                                           mediator_epochs=1))
    cli_upd = jax.jit(make_client_update(small_model, sgd(0.05), spec))

    k1, k2 = jax.random.split(key)
    x1, y1, m1 = _client_data(k1, 8, small_model, cls=1)
    x2, y2, m2 = _client_data(k2, 8, small_model, cls=3)
    xs = jnp.stack([x1, x2])
    ys = jnp.stack([y1, y2])
    ms = jnp.stack([m1, m2])

    delta = med_upd(params, xs, ys, ms, key)
    # manual sequential pass with the same per-client keys
    keys = jax.random.split(jax.random.split(key, 1)[0], 2)
    w = cli_upd(params, x1, y1, m1, keys[0])
    w = cli_upd(w, x2, y2, m2, keys[1])
    expect = jax.tree.map(lambda a, b: a - b, w, params)
    for d, e in zip(jax.tree.leaves(delta), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(d), np.asarray(e), atol=1e-5)


def test_fedavg_trainer_round_runs(tiny_federation, key):
    from repro.core.fedavg import FedAvgTrainer
    from repro.models.cnn import emnist_cnn
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    tr = FedAvgTrainer(model, adam(1e-3), tiny_federation, clients_per_round=4,
                       local=LocalSpec(10, 1), seed=0)
    hist = tr.fit(2, eval_every=2)
    assert hist and 0.0 <= hist[-1]["accuracy"] <= 1.0
    assert hist[-1]["traffic_mb"] > 0


def test_astraea_trainer_round_runs(tiny_federation):
    from repro.core.astraea import AstraeaTrainer
    from repro.models.cnn import emnist_cnn
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation, clients_per_round=6,
                        gamma=3, local=LocalSpec(10, 1), mediator_epochs=1,
                        alpha=0.67, aug_mode="materialized", seed=0)
    hist = tr.fit(2, eval_every=2)
    assert hist and 0.0 <= hist[-1]["accuracy"] <= 1.0
    assert tr.last_schedule_stats["num_mediators"] >= 2
    # materialized augmentation actually added data
    assert tr.extra_storage_frac > 0
    # the default (online) mode materializes nothing but reports the cost
    on = AstraeaTrainer(model, adam(1e-3), tiny_federation, clients_per_round=6,
                        gamma=3, local=LocalSpec(10, 1), mediator_epochs=1,
                        alpha=0.67, seed=0)
    assert on.aug_mode == "online" and on.extra_storage_frac == 0
    assert on.planned_extra_frac == pytest.approx(tr.extra_storage_frac)
    hist = on.fit(2, eval_every=2)
    assert hist and 0.0 <= hist[-1]["accuracy"] <= 1.0


def test_astraea_kernel_aggregation_matches(tiny_federation):
    from repro.core.astraea import AstraeaTrainer
    from repro.models.cnn import emnist_cnn
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    mk = lambda uk: AstraeaTrainer(model, sgd(0.05), tiny_federation,
                                   clients_per_round=4, gamma=2,
                                   local=LocalSpec(10, 1), alpha=None,
                                   use_kernel_agg=uk, seed=0)
    a, b = mk(False), mk(True)
    a.run_round()
    b.run_round()
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_comm_meter_formulas():
    from repro.core.comm import CommMeter
    m = CommMeter(num_params=1000, bytes_per_param=4)
    m.fedavg_round(c=10)
    assert m.total_bytes == 2 * 10 * 4000
    m2 = CommMeter(num_params=1000, bytes_per_param=4)
    m2.astraea_round(c=50, gamma=10, mediator_epochs=1)
    assert m2.total_bytes == 2 * 4000 * (5 + 50)   # paper: 2|w|(ceil(c/g)+c)


def test_reweighted_fedavg_runs_and_upweights_minority(tiny_federation):
    from repro.core.reweighting import (ReweightedFedAvgTrainer,
                                        inverse_frequency_weights)
    from repro.models.cnn import emnist_cnn
    import numpy as np
    counts = tiny_federation.client_counts().sum(0)
    w = inverse_frequency_weights(counts)
    assert w[np.argmin(counts)] == w.max()      # rarest class, biggest weight
    assert w.mean() == pytest.approx(1.0, rel=1e-5)

    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    tr = ReweightedFedAvgTrainer(model, adam(1e-3), tiny_federation,
                                 clients_per_round=4, local=LocalSpec(10, 1),
                                 seed=0)
    hist = tr.fit(2, eval_every=2)
    assert 0.0 <= hist[-1]["accuracy"] <= 1.0
