"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


@given(m=st.integers(1, 9), n=st.integers(1, 700),
       dt=st.sampled_from(DTYPES), block=st.sampled_from([128, 256]))
@settings(max_examples=25, deadline=None)
def test_fedavg_agg_matches_ref(m, n, dt, block):
    key = jax.random.PRNGKey(m * 1000 + n)
    deltas = jax.random.normal(key, (m, n), jnp.float32).astype(dt)
    weights = jax.random.uniform(jax.random.fold_in(key, 1), (m,)) * 10 + 0.1
    out = ops.fedavg_agg(deltas, weights, block_n=block)
    expect = ref.fedavg_agg(deltas, weights)
    tol = 1e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), rtol=tol, atol=tol)


@given(b=st.integers(1, 5), hw=st.sampled_from([8, 16, 28]),
       c=st.sampled_from([1, 3]), scale=st.floats(0.5, 2.5))
@settings(max_examples=10, deadline=None)
def test_affine_warp_matches_map_coordinates(b, hw, c, scale):
    """The fused one-launch warp kernel == the per-channel map_coordinates
    oracle (order=1, mode="constant"), incl. heavy out-of-bounds regimes
    (scale > 1 pulls source coords far outside the image)."""
    from repro.core.augmentation import warp_params
    key = jax.random.PRNGKey(b * 100 + hw + c)
    imgs = jax.random.normal(key, (b, hw, hw, c), jnp.float32)
    mats, trans = warp_params(jax.random.fold_in(key, 1), b)
    mats = mats * scale
    trans = trans * scale
    out = ops.affine_warp(imgs, mats, trans)
    expect = ref.affine_warp(imgs, mats, trans)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_affine_warp_identity_params():
    """Identity matrix + zero translation must reproduce the input exactly
    (integer source coords: the bilinear weights collapse to one corner)."""
    imgs = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 12, 3))
    mats = jnp.broadcast_to(jnp.eye(2), (2, 2, 2))
    trans = jnp.zeros((2, 2))
    np.testing.assert_allclose(np.asarray(ops.affine_warp(imgs, mats, trans)),
                               np.asarray(imgs), atol=1e-6)


def test_warp_batch_impls_agree(key):
    """augmentation.warp_batch routes the same draws through either
    resampler; "pallas" and "reference" must agree to fp32 round-off."""
    from repro.core import augmentation as aug
    imgs = jax.random.normal(key, (4, 16, 16, 1), jnp.float32)
    a = aug.warp_batch(key, imgs, impl="reference")
    b = aug.warp_batch(key, imgs, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    with pytest.raises(ValueError, match="impl"):
        aug.warp_batch(key, imgs, impl="nearest")


def test_fedavg_agg_tree_shapes(key):
    tree = {"a": jax.random.normal(key, (3, 4, 5)),
            "b": {"c": jax.random.normal(key, (3, 7))}}
    w = jnp.asarray([1.0, 2.0, 3.0])
    out = ops.fedavg_agg_tree(tree, w)
    assert out["a"].shape == (4, 5)
    assert out["b"]["c"].shape == (7,)
    expect = jax.tree.map(lambda d: ref.fedavg_agg(d.reshape(3, -1), w).reshape(d.shape[1:]), tree)
    for o, e in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e), rtol=1e-5)


def test_fedavg_agg_m1_and_unaligned_n():
    """M=1 (single mediator) and N off the 128/BLOCK_N grid: the padding
    rows/columns introduced by the 2-D tiling must be exact no-ops."""
    for m, n in ((1, 130), (1, 2049), (5, 1000)):
        key = jax.random.PRNGKey(m * 7919 + n)
        d = jax.random.normal(key, (m, n), jnp.float32)
        w = jax.random.uniform(jax.random.fold_in(key, 1), (m,)) + 0.1
        out = ops.fedavg_agg(d, w)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.fedavg_agg(d, w)),
                                   rtol=1e-5, atol=1e-5)


def test_fedavg_agg_block_m_chunking(key):
    """M spanning several BLOCK_M chunks: the sequential VMEM-accumulator
    reduction over mediator blocks matches the single-chunk launch and
    the einsum oracle."""
    d = jax.random.normal(key, (17, 300), jnp.float32)
    w = jnp.arange(1.0, 18.0)
    expect = np.asarray(ref.fedavg_agg(d, w))
    for block_m in (4, 8, 32):
        out = np.asarray(ops.fedavg_agg(d, w, block_m=block_m))
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_fedavg_agg_bf16_fp32_accumulation(key):
    """bf16 deltas: output stays bf16 (wire dtype) but every product and
    partial sum is fp32 -- the result must track the fp32 oracle computed
    on the same (bf16-rounded) values to bf16 round-off, not bf16
    accumulation error."""
    d16 = jax.random.normal(key, (9, 257), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (9,)) * 5 + 0.1
    out = ops.fedavg_agg(d16, w)
    assert out.dtype == jnp.bfloat16
    full = np.asarray(ref.fedavg_agg(d16.astype(jnp.float32), w))
    np.testing.assert_allclose(np.asarray(out, np.float32), full,
                               rtol=2e-2, atol=2e-2)


def test_fedavg_agg_tree_fused_matches_per_leaf(key):
    """The single flattened (M, total_params) launch == the per-leaf path,
    bitwise: each column reduces independently, fusion only changes tiling."""
    tree = {"w1": jax.random.normal(key, (4, 6, 3)),
            "b1": jax.random.normal(jax.random.fold_in(key, 1), (4, 3)),
            "w2": jax.random.normal(jax.random.fold_in(key, 2), (4, 129))}
    w = jnp.asarray([3.0, 0.0, 1.5, 7.0])
    per_leaf = ops.fedavg_agg_tree(tree, w, fuse=False, block_n=128)
    fused = ops.fedavg_agg_tree(tree, w, fuse=True, block_n=128)
    assert jax.tree.structure(per_leaf) == jax.tree.structure(fused)
    for o, e in zip(jax.tree.leaves(fused), jax.tree.leaves(per_leaf)):
        assert o.shape == e.shape and o.dtype == e.dtype
        np.testing.assert_array_equal(np.asarray(o), np.asarray(e))


def test_fedavg_agg_tree_mixed_dtypes_bitwise(key):
    """A bf16/f32 mixed tree fuses into one launch PER DTYPE GROUP; every
    leaf keeps its wire dtype and matches the per-leaf path bitwise."""
    tree = {"f32a": jax.random.normal(key, (4, 130)),
            "bf16": jax.random.normal(jax.random.fold_in(key, 1),
                                      (4, 96)).astype(jnp.bfloat16),
            "f32b": jax.random.normal(jax.random.fold_in(key, 2), (4, 7, 5))}
    w = jnp.asarray([2.0, 1.0, 0.0, 4.5])
    fused = ops.fedavg_agg_tree(tree, w, fuse=True, block_n=128)
    per_leaf = ops.fedavg_agg_tree(tree, w, fuse=False, block_n=128)
    for o, e in zip(jax.tree.leaves(fused), jax.tree.leaves(per_leaf)):
        assert o.dtype == e.dtype
        np.testing.assert_array_equal(np.asarray(o, np.float32),
                                      np.asarray(e, np.float32))


@given(k=st.integers(1, 300), c=st.integers(2, 64))
@settings(max_examples=25, deadline=None)
def test_kld_score_matches_ref(k, c):
    key = jax.random.PRNGKey(k * 100 + c)
    med = jax.random.uniform(key, (c,)) * 100
    cli = jax.random.uniform(jax.random.fold_in(key, 1), (k, c)) * 50
    out = ops.kld_score(med, cli)
    expect = ref.kld_score(med, cli)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_kld_score_zero_rows():
    """All-zero candidate rows (padding) must not produce NaNs."""
    med = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    cli = jnp.zeros((5, 4))
    out = np.asarray(ops.kld_score(med, cli))
    assert np.isfinite(out).all()


@given(m=st.integers(1, 20), k=st.integers(1, 100), c=st.integers(2, 32))
@settings(max_examples=15, deadline=None)
def test_kld_score_matrix_matches_ref(m, k, c):
    """The one-launch (M, K, C) sweep == the vmapped per-mediator oracle,
    and each row == the per-mediator kernel bitwise (same f32 ops)."""
    key = jax.random.PRNGKey(m * 10000 + k * 100 + c)
    meds = jax.random.uniform(key, (m, c)) * 100
    cli = jax.random.uniform(jax.random.fold_in(key, 1), (k, c)) * 50
    out = ops.kld_score_matrix(meds, cli)
    assert out.shape == (m, k)
    expect = ref.kld_score_matrix(meds, cli)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)
    row = ops.kld_score(meds[0], cli)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(row))


def test_kld_score_matrix_zero_histograms():
    """Zero-histogram clients AND mediators (padding rows / an empty open
    mediator) must score finite -- the masked p>0 row-sum handles p=0."""
    meds = jnp.concatenate([jnp.zeros((1, 5)),
                            jnp.ones((2, 5)) * 3.0])
    cli = jnp.concatenate([jnp.zeros((2, 5)),
                           jnp.ones((3, 5)) * 2.0])
    out = np.asarray(ops.kld_score_matrix(meds, cli))
    assert out.shape == (3, 5) and np.isfinite(out).all()
    # all-zero merged histogram scores exactly 0 (the empty sum)
    assert out[0, 0] == 0.0


@given(seed=st.integers(0, 100), k=st.integers(1, 24), c=st.integers(2, 8),
       gamma=st.integers(1, 5), block_k=st.sampled_from([4, 256]))
@settings(max_examples=12, deadline=None)
def test_kld_greedy_picks_matches_scan(seed, k, c, gamma, block_k):
    """The one-launch Alg. 3 kernel == the jitted masked-argmin lax.scan,
    bitwise, across block sizes (cross-block strict-< tie combining) and
    integer histograms (heavy ties)."""
    from repro.core import scheduling
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, 30, (k, c)), jnp.float32)
    picks = np.asarray(ops.kld_greedy_picks(counts, gamma, block_k=block_k))
    expect = np.asarray(scheduling._greedy_picks(counts, gamma))
    np.testing.assert_array_equal(picks, expect)


def test_kld_greedy_picks_all_ties_ascending():
    """Identical histograms tie at every step; the first-minimum rule
    (within-block argmin + strict-< cross-block combine) must yield
    ascending client ids, including across BLOCK_K boundaries."""
    counts = jnp.tile(jnp.asarray([[2.0, 1.0, 0.0]]), (9, 1))
    picks = np.asarray(ops.kld_greedy_picks(counts, 4, block_k=4))
    np.testing.assert_array_equal(picks, np.arange(9))


@pytest.mark.parametrize("s,heads,kv,hd", [(128, 4, 4, 64), (256, 4, 2, 64),
                                           (256, 8, 1, 128)])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("dt", DTYPES)
def test_flash_attention_matches_ref(s, heads, kv, hd, window, dt):
    key = jax.random.PRNGKey(s + heads)
    q = jax.random.normal(key, (2, s, heads, hd), jnp.float32).astype(dt)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, kv, hd), jnp.float32).astype(dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, kv, hd), jnp.float32).astype(dt)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    n_rep = heads // kv
    kr = jnp.repeat(k, n_rep, axis=2)
    vr = jnp.repeat(v, n_rep, axis=2)
    expect = ref.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(kr, 1, 2),
                                 jnp.swapaxes(vr, 1, 2), causal=True, window=window)
    expect = jnp.swapaxes(expect, 1, 2)
    tol = 2e-4 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), rtol=tol, atol=tol)


@given(nq=st.sampled_from([64, 128]), nk=st.sampled_from([64, 128]),
       s=st.sampled_from([128, 256]))
@settings(max_examples=10, deadline=None)
def test_flash_block_shape_invariance(nq, nk, s):
    """Output must not depend on the chosen BlockSpec tiling."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, s, 2, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, 64))
    a = ops.flash_attention(q, k, v, block_q=nq, block_k=nk)
    b = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_flash_q_offset_decodes_chunk():
    """Chunked prefill: second chunk with q_offset == full-sequence slice."""
    key = jax.random.PRNGKey(9)
    s = 256
    q = jax.random.normal(key, (1, s, 2, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, 64))
    full = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    # second half of q against ALL of k/v with offset
    half = ops.flash_attention(q[:, s // 2:], k, v, causal=True,
                               q_offset=s // 2, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full[:, s // 2:]),
                               rtol=2e-5, atol=2e-5)
