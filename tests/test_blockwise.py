"""Blockwise (flash-emulation) attention vs reference (§Perf H4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def _qkv(key, s, h=2, hd=64, b=1):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, h, hd)),
            jax.random.normal(ks[1], (b, s, h, hd)),
            jax.random.normal(ks[2], (b, s, h, hd)))


@pytest.mark.parametrize("window", [None, 128, 1024])
def test_blockwise_matches_reference(key, window):
    q, k, v = _qkv(key, 2048)
    a = L.blockwise_attention(q, k, v, causal=True, window=window, block_k=256)
    b = L.attention_scores(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@given(block=st.sampled_from([128, 256, 512]), seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_blockwise_block_size_invariance(block, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1024)
    a = L.blockwise_attention(q, k, v, causal=True, block_k=block)
    b = L.blockwise_attention(q, k, v, causal=True, block_k=1024)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_blockwise_gradients_match_reference(key):
    q, k, v = _qkv(key, 1024)

    def loss_block(q, k, v):
        return (L.blockwise_attention(q, k, v, causal=True, block_k=256) ** 2).sum()

    def loss_ref(q, k, v):
        return (L.attention_scores(q, k, v, causal=True) ** 2).sum()

    ga = jax.grad(loss_block, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-3)


def test_gqa_dispatches_to_blockwise(key, monkeypatch):
    """seq >= threshold routes through the blockwise path (same numbers)."""
    q, k, v = _qkv(key, 2048)
    monkeypatch.setattr(L, "BLOCKWISE_ATTENTION", True)
    a = L.gqa_attention(q, k, v, causal=True)
    monkeypatch.setattr(L, "BLOCKWISE_ATTENTION", False)
    b = L.gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_pallas_kernel_matches_blockwise(key):
    """The Pallas flash kernel (interpret) and the XLA blockwise lowering
    are the same algorithm -- outputs must agree tightly."""
    from repro.kernels import ops
    q, k, v = _qkv(key, 512, h=4)
    a = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    qt = jnp.swapaxes(q, 1, 2)
    b = L.blockwise_attention(q, k, v, causal=True, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s_len,W", [(512, 128), (1024, 256), (2048, 512)])
def test_local_window_matches_reference(key, s_len, W):
    """§Perf H8: exact 2-chunk local attention == masked SWA reference."""
    q, k, v = _qkv(key, s_len, h=3, hd=32)
    a = L.local_window_attention(q, k, v, W)
    b = L.attention_scores(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_local_window_gradients(key):
    q, k, v = _qkv(key, 512, h=2, hd=32)

    def f(path):
        return (path(q, k, v) ** 2).sum()

    ga = jax.grad(lambda q_: (L.local_window_attention(q_, k, v, 128) ** 2).sum())(q)
    gb = jax.grad(lambda q_: (L.attention_scores(q_, k, v, causal=True,
                                                 window=128) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=3e-3, atol=3e-3)
