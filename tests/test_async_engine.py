"""Bounded-staleness async round subsystem (core/async_engine.py).

The acceptance claims: the S=0 async trajectory is BITWISE-identical to the
synchronous engine (on 1 and 4 forced host devices, across stores, waves
and reschedules), ``num_round_traces`` stays 1 no matter how many waves
execute, the staleness bound is enforced by construction, and a 4x
straggler yields a >= 1.5x simulated round-time reduction at S=1."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import LocalSpec, scheduling
from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.staleness import (StragglerModel, StragglerSpec,
                                  make_staleness_policy)
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


FOUR_X = StragglerSpec(model="fixed", straggler_frac=0.34, slowdown=4.0,
                       seed=0)


def _params_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _sync_async_pair(model, fed, cfg, spec, rounds, mesh_size=1):
    sync = FLRoundEngine(model, adam(1e-3), fed, cfg,
                         mesh=make_mediator_mesh(mesh_size))
    for _ in range(rounds):
        sync.run_round()
    eng = FLRoundEngine(model, adam(1e-3), fed, cfg,
                        mesh=make_mediator_mesh(mesh_size))
    a = AsyncRoundEngine(eng, spec)
    for _ in range(rounds):
        a.run_round()
    return sync, a


def test_s0_single_wave_bitwise_matches_sync(model, tiny_federation):
    """S=0 with one wave is the synchronous barrier, bit for bit."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=2, donate_params=False)
    sync, a = _sync_async_pair(model, tiny_federation, cfg,
                               AsyncSpec(staleness_bound=0, wave_size=0),
                               rounds=2)
    _params_bitwise(sync.params, a.params)
    assert a.engine.num_round_traces == 1


def test_s0_multi_wave_bitwise_across_reschedules(model, tiny_federation):
    """The real claim: waves execute separately (straggler-ordered,
    1 mediator each), yet S=0 commits reproduce the synchronous engine
    bitwise -- across per-round KLD reschedules, on one trace."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=3, donate_params=False,
                               reschedule_every_round=True)
    spec = AsyncSpec(staleness_bound=0, wave_size=1,
                     straggler=StragglerSpec(model="lognormal", seed=3))
    sync, a = _sync_async_pair(model, tiny_federation, cfg, spec, rounds=3)
    _params_bitwise(sync.params, a.params)
    assert a.engine.num_round_traces == 1
    assert a.engine.num_schedule_packs == 3
    assert a.num_commits == 3 and not a._pending      # S=0 never defers


def test_s0_fedavg_weights_path_bitwise(model, tiny_federation):
    """The gamma=1 full-weight aggregation path through async waves."""
    cfg = EngineConfig.fedavg(clients_per_round=4, local=LocalSpec(10, 1),
                              seed=0, pad_mediators_to=4,
                              donate_params=False)
    spec = AsyncSpec(staleness_bound=0, wave_size=2,
                     straggler=StragglerSpec(model="lognormal", seed=5))
    sync, a = _sync_async_pair(model, tiny_federation, cfg, spec, rounds=3)
    _params_bitwise(sync.params, a.params)
    assert a.engine.num_round_traces == 1


def test_bounded_staleness_defers_discounts_and_speeds_up(model,
                                                          tiny_federation):
    """S=1 under a 4x straggler: the straggler wave lands one round late
    (never later), every contribution eventually folds, and the simulated
    round time beats the synchronous barrier by >= 1.5x."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=2,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=3, donate_params=False)
    rounds = 6
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=1, wave_size=1,
                                        straggler=FOUR_X))
    for _ in range(rounds):
        a.run_round()
    assert a._pending                   # the straggler is in flight...
    a.flush()
    assert not a._pending               # ...and the final fold lands it
    stales = [s for c in a.commit_log for s in c["staleness"]]
    assert max(stales) == 1             # bound enforced, overlap happened
    assert sum(c["folded_rows"] for c in a.commit_log) == rounds * 3
    assert a.sim_speedup >= 1.5         # 4x straggler off the critical path
    assert a.virtual_time < a.sync_time
    assert a.engine.num_round_traces == 1


def test_fit_flushes_on_every_call(model, tiny_federation):
    """Repeated fit() calls must each flush their pending stragglers --
    the gate is the call's own last round, not the absolute counter."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=2,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=3, donate_params=False)
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=1, wave_size=1,
                                        straggler=FOUR_X))
    a.fit(2, eval_every=2)
    assert not a._pending
    a.fit(2, eval_every=2)
    assert not a._pending
    assert sum(c["folded_rows"] for c in a.commit_log) == 4 * 3


def test_async_final_accuracy_tracks_sync(model, tiny_federation):
    """Equal-final-accuracy tolerance: the staleness-discounted trajectory
    stays close to the synchronous one on the same federation."""
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=2,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=3, donate_params=False)
    rounds = 8
    sync = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                         mesh=make_mediator_mesh(1))
    sh = sync.fit(rounds, eval_every=rounds)
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(staleness_bound=1, wave_size=1,
                                        straggler=FOUR_X))
    ah = a.fit(rounds, eval_every=rounds)
    assert abs(ah[-1]["accuracy"] - sh[-1]["accuracy"]) <= 0.1
    assert ah[-1]["sim_speedup"] >= 1.5
    assert ah[-1]["staleness_max"] <= 1


def test_async_spec_through_both_trainers(tiny_federation):
    """async_spec plumbs through AstraeaTrainer and FedAvgTrainer; the
    S=0 trainer trajectory equals the synchronous trainer bitwise."""
    from repro.core.astraea import AstraeaTrainer
    from repro.core.fedavg import FedAvgTrainer
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    spec = AsyncSpec(staleness_bound=0, wave_size=1,
                     straggler=StragglerSpec(model="lognormal", seed=3))
    kw = dict(clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
              alpha=None, seed=0, mesh=make_mediator_mesh(1))
    plain = AstraeaTrainer(model, adam(1e-3), tiny_federation, **kw)
    plain.run_round()
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        async_spec=spec, **kw)
    tr.run_round()
    _params_bitwise(plain.params, tr.params)
    assert isinstance(tr.runner, AsyncRoundEngine)

    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=4, local=LocalSpec(10, 1), seed=0,
                       async_spec=AsyncSpec(staleness_bound=1, wave_size=2,
                                            straggler=FOUR_X),
                       mesh=make_mediator_mesh(1))
    hist = fa.fit(3, eval_every=3)
    assert hist[-1]["sim_speedup"] > 0 and "staleness_mean" in hist[-1]
    assert fa.engine.num_round_traces == 1


def test_staleness_policies_are_exact_at_zero():
    for name in ("constant", "polynomial", "exponential"):
        lam = make_staleness_policy(name, alpha=0.5)
        assert lam(0) == 1.0            # exactly: the bitwise S=0 guarantee
        vals = [lam(s) for s in range(5)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))  # non-increasing
        assert all(v > 0 for v in vals)
    assert make_staleness_policy("constant")(7) == 1.0
    assert make_staleness_policy("polynomial", 1.0)(1) == pytest.approx(0.5)
    with pytest.raises(ValueError, match="staleness policy"):
        make_staleness_policy("linear")


def test_straggler_model_deterministic_and_seeded():
    spec = StragglerSpec(model="fixed", straggler_frac=0.25, slowdown=4.0,
                         seed=7)
    a, b = StragglerModel(spec, 8), StragglerModel(spec, 8)
    np.testing.assert_array_equal(a.factors, b.factors)
    assert (a.factors == 4.0).sum() == 2 and (a.factors == 1.0).sum() == 6
    c = StragglerModel(dataclasses.replace(spec, seed=8), 8)
    assert not np.array_equal(a.factors, c.factors)
    none = StragglerModel(StragglerSpec(), 5)
    np.testing.assert_array_equal(none.factors, np.ones(5))
    work = np.array([2.0, 3.0])
    np.testing.assert_array_equal(none.durations(work), work)
    with pytest.raises(ValueError, match="straggler model"):
        StragglerSpec(model="uniform")
    with pytest.raises(ValueError, match="slots"):
        none.durations(np.ones(9))


def test_partition_waves_coschedules_stragglers():
    durations = np.array([1.0, 8.0, 1.5, 7.5, 1.2, 1.1])
    waves, stats = scheduling.partition_waves(durations, 2)
    assert sorted(i for w in waves for i in w) == list(range(6))
    assert all(len(w) <= 2 for w in waves)
    assert waves[-1] == [3, 1]          # both stragglers share the last wave
    assert stats["wave_times"] == sorted(stats["wave_times"])
    assert stats["barrier_time"] == 8.0
    assert stats["blocked_time_saved"] > 0   # vs schedule-order chunking
    one, s1 = scheduling.partition_waves(durations, 0)
    assert len(one) == 1 and s1["wave_times"] == [8.0]
    with pytest.raises(ValueError, match="zero mediators"):
        scheduling.partition_waves(np.array([]), 2)


def test_async_spec_validation():
    with pytest.raises(ValueError, match="staleness_bound"):
        AsyncSpec(staleness_bound=-1)
    with pytest.raises(ValueError, match="staleness policy"):
        AsyncSpec(policy="bogus")
    with pytest.raises(ValueError, match="straggler_frac"):
        StragglerSpec(model="fixed", straggler_frac=1.5)


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.staleness import StragglerSpec
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)
    aspec = AsyncSpec(staleness_bound=0, wave_size=1,
                      straggler=StragglerSpec(model="lognormal", seed=3))
    for store in ("replicated", "sharded"):
        cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                                   local=LocalSpec(10, 1), seed=0,
                                   pad_mediators_to=4, donate_params=False,
                                   reschedule_every_round=True, store=store)
        sync = FLRoundEngine(model, adam(1e-3), fed, cfg,
                             mesh=make_mediator_mesh(4))
        sync.run_round()
        sync.run_round()
        eng = FLRoundEngine(model, adam(1e-3), fed, cfg,
                            mesh=make_mediator_mesh(4))
        a = AsyncRoundEngine(eng, aspec)
        a.run_round()
        a.run_round()
        for x, y in zip(jax.tree.leaves(sync.params),
                        jax.tree.leaves(a.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert eng.num_round_traces == 1, eng.num_round_traces
    print("OK")
""")


def test_async_multi_device_mesh(tmp_path):
    """S=0 waves == sync on a real 4-device mediator mesh (replicated AND
    client-sharded stores), one trace. Subprocess: the device count must
    be forced before jax initializes."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
