"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree, load_pytree, save_trainer, load_trainer


def test_roundtrip_nested(tmp_path, key):
    tree = {
        "w": jax.random.normal(key, (17, 5)),
        "nested": {"b": jnp.arange(8, dtype=jnp.int32),
                   "scalars": [1, 2.5, "name"]},
        "tup": (jnp.ones((2, 2), jnp.bfloat16), None),
    }
    p = str(tmp_path / "x.ckpt")
    save_pytree(p, tree, metadata={"round": 3})
    back = load_pytree(p)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(tree["w"]))
    assert back["nested"]["scalars"] == [1, 2.5, "name"]
    assert back["tup"][0].dtype == jnp.bfloat16
    assert back["tup"][1] is None
    import json, os
    meta = json.load(open(p + ".meta.json"))
    assert meta["round"] == 3


def test_trainer_roundtrip(tmp_path, tiny_federation):
    from repro.core import LocalSpec
    from repro.core.fedavg import FedAvgTrainer
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam
    model = emnist_cnn(tiny_federation.num_classes, image_size=16)
    tr = FedAvgTrainer(model, adam(1e-3), tiny_federation, clients_per_round=3,
                       local=LocalSpec(10, 1), seed=0)
    tr.run_round()
    p = str(tmp_path / "t.ckpt")
    save_trainer(p, tr)

    tr2 = FedAvgTrainer(model, adam(1e-3), tiny_federation, clients_per_round=3,
                        local=LocalSpec(10, 1), seed=0)
    load_trainer(p, tr2)
    assert tr2._round == 1
    assert tr2.comm.total_bytes == tr.comm.total_bytes
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
