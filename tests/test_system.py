"""End-to-end behaviour tests of the paper's system (scaled to CPU):

1. Global imbalance degrades FedAvg accuracy (Section II-B motivation).
2. Astraea (augmentation + mediators) recovers accuracy over FedAvg.
3. Mediator KLD drops below 0.2 (Fig. 7).
4. Astraea reaches a target accuracy with less traffic than FedAvg (Tab. III).

These train real (tiny) CNNs for a handful of rounds -- directional but
deterministic assertions; the full-size sweep lives in benchmarks/.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.core.fedavg import FedAvgTrainer
from repro.data.federated import partition, EMNIST_LIKE
from repro.models.cnn import emnist_cnn
from repro.optim import adam

SPEC = dataclasses.replace(EMNIST_LIKE, num_classes=10, image_size=16,
                           noise=0.45, distort=0.35)
NC, TOTAL, TEST = 16, 1400, 600
ROUNDS = 12
LOCAL = LocalSpec(batch_size=20, epochs=2)


def _fed(global_dist, seed=0, name="d"):
    return partition(SPEC, num_clients=NC, total_samples=TOTAL, test_samples=TEST,
                     sizes="instagram", global_dist=global_dist, local="random",
                     seed=seed, name=name)


@pytest.fixture(scope="module")
def model():
    return emnist_cnn(SPEC.num_classes, image_size=16)


@pytest.fixture(scope="module")
def balanced_acc(model):
    tr = FedAvgTrainer(model, adam(1e-3), _fed("balanced", name="BAL"),
                       clients_per_round=8, local=LOCAL, seed=0)
    return max(h["accuracy"] for h in tr.fit(ROUNDS, eval_every=4))


@pytest.fixture(scope="module")
def imbalanced_fedavg(model):
    tr = FedAvgTrainer(model, adam(1e-3), _fed("letterfreq", name="LTRF"),
                       clients_per_round=8, local=LOCAL, seed=0)
    hist = tr.fit(ROUNDS, eval_every=4)
    best = max(hist, key=lambda h: h["accuracy"])
    return tr, best


@pytest.fixture(scope="module")
def astraea_run(model):
    tr = AstraeaTrainer(model, adam(1e-3), _fed("letterfreq", name="LTRF"),
                        clients_per_round=8, gamma=4, local=LOCAL,
                        mediator_epochs=1, alpha=0.67, seed=0)
    hist = tr.fit(ROUNDS, eval_every=2)
    best = max(hist, key=lambda h: h["accuracy"])
    return tr, best


def test_global_imbalance_degrades_fedavg(balanced_acc, imbalanced_fedavg):
    """Directional at this scale; the quantitative gap is measured at
    benchmark scale (EXPERIMENTS.md §Claims: -4.0%, paper -7.9%)."""
    _, last = imbalanced_fedavg
    assert last["accuracy"] < balanced_acc + 0.02, \
        f"imbalance unexpectedly helps: {last['accuracy']:.3f} vs balanced {balanced_acc:.3f}"


def test_minority_class_recall_collapses(imbalanced_fedavg):
    """Paper Fig. 1(c): under global imbalance the rare classes are the
    ones the FedAvg model stops predicting -- a sharper, more deterministic
    signature than the total-accuracy delta."""
    import numpy as np
    from repro.core.fl import confusion_matrix
    from repro.data.federated import letter_frequency_probs
    tr, _ = imbalanced_fedavg
    fed = tr.data
    _, recall = confusion_matrix(tr.model, tr.params, fed.test_images,
                                 fed.test_labels, fed.num_classes)
    order = np.argsort(-letter_frequency_probs(fed.num_classes))
    majority = recall[order[:3]].mean()
    minority = recall[order[-3:]].mean()
    assert majority > minority + 0.05, (majority, minority)


def test_astraea_recovers_accuracy(imbalanced_fedavg, astraea_run):
    _, fed = imbalanced_fedavg
    _, ast = astraea_run
    assert ast["accuracy"] > fed["accuracy"] + 0.02, \
        f"Astraea {ast['accuracy']:.3f} should beat FedAvg {fed['accuracy']:.3f}"


def test_mediator_kld_below_threshold(astraea_run):
    tr, last = astraea_run
    assert last["mediator_kld_mean"] < 0.2      # paper Fig. 7: 0.125


def test_astraea_converges_in_fewer_rounds(imbalanced_fedavg, astraea_run):
    """Table III mechanism at CPU scale: Astraea reaches FedAvg's best
    accuracy in at most ~3/4 of the rounds (benchmarks measure 0.45x; the
    paper's bytes ratio additionally needs its 500-client crawl regime --
    see EXPERIMENTS.md §Claims)."""
    fed_tr, fed = imbalanced_fedavg
    ast_tr, _ = astraea_run
    target = fed["accuracy"]
    reached = [h for h in ast_tr.history if h["accuracy"] >= target]
    assert reached, "Astraea never reached FedAvg best accuracy"
    assert reached[0]["round"] <= max(fed["round"], 2)
