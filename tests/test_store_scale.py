"""Million-client streaming path, shrunk to CI scale.

The tentpole claim: with a lazy ``StreamingFederation`` feeding the
host/spilled stores, the per-device footprint is a function of the round
(``U_cap`` rows), NOT of K -- so K=5e4 (here) and K=1e6 (the committed
``experiments/results/store.json`` curves) run the same executable over
the same bytes. Bitwise: the streamed engines reproduce the materialized
replicated engine exactly, and the spill tier's async prefetch changes
when rows are read, never the trajectory.

The CI scale-smoke leg runs exactly this file (see ci.yml) under a hard
job timeout so a scaling regression fails fast instead of hanging."""
import jax
import numpy as np
import pytest

from repro.core import LocalSpec
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.data.synthetic import (SyntheticSpec, StreamingFederation,
                                  federation_counts)
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam

SPEC = SyntheticSpec(num_classes=8, image_size=16)


@pytest.fixture(scope="module")
def model():
    return emnist_cnn(SPEC.num_classes, image_size=16)


def _stream(k, seed=5):
    return StreamingFederation(SPEC, federation_counts(k, SPEC.num_classes,
                                                       seed=seed),
                               batch_size=12, seed=seed)


def _cfg(store):
    return EngineConfig.astraea(clients_per_round=8, gamma=4,
                                local=LocalSpec(12, 1), seed=0,
                                pad_mediators_to=2, store=store,
                                reschedule_every_round=True)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_streaming_matches_materialized_bitwise(model):
    """A small streamed federation == its materialized packed copy,
    bitwise, under both streaming policies (same padding rule, same
    per-client bytes, same schedule RNG stream)."""
    fed = _stream(32, seed=3)
    mesh = make_mediator_mesh(1)
    ref = FLRoundEngine(model, adam(1e-3), fed.materialize(),
                        _cfg("replicated"), mesh=mesh)
    ref.run_round()
    ref.run_round()
    for store in ("host", "spilled"):
        eng = FLRoundEngine(model, adam(1e-3), fed, _cfg(store), mesh=mesh)
        eng.run_round()
        eng.run_round()
        _params_equal(eng, ref)
        assert eng.num_round_traces == 1


def test_streaming_rejects_non_streaming_policies(model):
    """Policies that need the packed arrays cannot adopt a row source."""
    with pytest.raises(ValueError, match="streaming|packed"):
        FLRoundEngine(model, adam(1e-3), _stream(16), _cfg("replicated"),
                      mesh=make_mediator_mesh(1))


def test_scale_smoke_50k_clients_fixed_footprint(model):
    """The CI scale leg: K=5e4 completes rounds with a device footprint
    identical to K=1e3, host == spilled bitwise, one trace, and the
    spill tier's prefetch overlapped the rounds."""
    mesh = make_mediator_mesh(1)
    fed = _stream(50_000)
    host = FLRoundEngine(model, adam(1e-3), fed, _cfg("host"), mesh=mesh)
    sp = FLRoundEngine(model, adam(1e-3), fed, _cfg("spilled"), mesh=mesh)
    for _ in range(2):
        host.run_round()
        sp.run_round()
    _params_equal(host, sp)
    assert host.num_round_traces == 1 and sp.num_round_traces == 1
    assert sp.store.prefetch_hits >= 1 and sp.store.prefetch_misses == 0
    # every staged row is accounted to exactly one tier
    stats = sp.store.stats()
    assert stats["tier_rows"] + stats["cache_hit_rows"] > 0
    assert host.comm.store_stream_bytes == sp.comm.store_stream_bytes > 0
    # footprint is U_cap rows regardless of K
    small = FLRoundEngine(model, adam(1e-3), _stream(1_000), _cfg("host"),
                          mesh=mesh)
    assert small.store.per_device_bytes() == host.store.per_device_bytes()
