"""Algorithm 3 tests: greedy mediator rescheduling."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheduling


def _random_counts(rng, k=20, c=10, skew=True):
    if skew:
        counts = np.zeros((k, c))
        for i in range(k):
            cls = rng.choice(c, size=2, replace=False)
            counts[i, cls] = rng.integers(10, 60, 2)
        return counts
    return rng.integers(1, 50, (k, c)).astype(float)


def test_every_client_assigned_once():
    rng = np.random.default_rng(0)
    counts = _random_counts(rng)
    meds = scheduling.reschedule(counts, gamma=4)
    seen = [c for m in meds for c in m.clients]
    assert sorted(seen) == list(range(20))
    assert all(len(m.clients) <= 4 for m in meds)


@given(st.integers(1, 7), st.integers(5, 30))
@settings(max_examples=20, deadline=None)
def test_gamma_respected(gamma, k):
    rng = np.random.default_rng(gamma * 100 + k)
    counts = _random_counts(rng, k=k)
    meds = scheduling.reschedule(counts, gamma=gamma)
    assert all(len(m.clients) <= gamma for m in meds)
    assert sum(len(m.clients) for m in meds) == k


def test_greedy_beats_random_on_skewed_clients():
    """Fig. 7: the KLD of greedy mediators is far below arbitrary grouping."""
    rng = np.random.default_rng(42)
    counts = _random_counts(rng, k=40, c=10, skew=True)
    greedy = scheduling.schedule_stats(scheduling.reschedule(counts, gamma=8))
    rand = scheduling.schedule_stats(
        scheduling.random_schedule(40, 8, counts, seed=0))
    assert greedy["kld_mean"] < rand["kld_mean"]
    assert greedy["kld_mean"] < 0.2      # paper: mediators reach < 0.2


def test_complementary_clients_pair_up():
    """Clients G (classes 0,1) and H (classes 2,3) should share a mediator."""
    counts = np.array([
        [10, 10, 0, 0],
        [0, 0, 10, 10],
        [10, 10, 0, 0],
        [0, 0, 10, 10],
    ], float)
    meds = scheduling.reschedule(counts, gamma=2)
    for m in meds:
        kinds = {tuple(counts[c] > 0) for c in m.clients}
        assert len(kinds) == 2           # each mediator mixes both skews


def test_kernel_scoring_matches_reference():
    rng = np.random.default_rng(3)
    counts = _random_counts(rng, k=25, c=12)
    m_ref = scheduling.reschedule(counts, gamma=5, use_kernel=False)
    m_ker = scheduling.reschedule(counts, gamma=5, use_kernel=True)
    assert [m.clients for m in m_ref] == [m.clients for m in m_ker]


@given(st.integers(0, 200), st.integers(4, 18), st.integers(1, 5),
       st.booleans())
@settings(max_examples=10, deadline=None)
def test_batched_reschedule_matches_numpy_loop(seed, k, gamma, skew):
    """The device-resident batched Alg. 3 (masked-argmin lax.scan)
    reproduces the numpy reference loop exactly -- same clients in the
    same absorption order, same mediator boundaries, ties included."""
    rng = np.random.default_rng(seed)
    counts = _random_counts(rng, k=k, skew=skew)
    loop = scheduling.reschedule(counts, gamma, impl="loop")
    bat = scheduling.reschedule(counts, gamma, impl="batched")
    assert [m.clients for m in loop] == [m.clients for m in bat]
    for a, b in zip(loop, bat):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_batched_reschedule_duplicate_clients_tie_break():
    """Identical histograms force score ties at every step: both impls
    must break them toward the lowest unassigned client id."""
    counts = np.tile(np.array([[3.0, 1.0, 0.0]]), (7, 1))
    loop = scheduling.reschedule(counts, gamma=3, impl="loop")
    bat = scheduling.reschedule(counts, gamma=3, impl="batched")
    assert [m.clients for m in loop] == [m.clients for m in bat] == \
        [[0, 1, 2], [3, 4, 5], [6]]


@given(st.integers(0, 150), st.integers(2, 16), st.integers(1, 4),
       st.booleans())
@settings(max_examples=8, deadline=None)
def test_kernel_batched_reschedule_matches_loop(seed, k, gamma, skew):
    """impl="batched" + use_kernel=True (the ONE-launch Pallas greedy
    pass) == the numpy loop oracle: same clients, same absorption order,
    same mediator histograms, ties included."""
    rng = np.random.default_rng(seed)
    counts = _random_counts(rng, k=k, skew=skew)
    loop = scheduling.reschedule(counts, gamma, impl="loop")
    ker = scheduling.reschedule(counts, gamma, impl="batched",
                                use_kernel=True)
    assert [m.clients for m in loop] == [m.clients for m in ker]
    for a, b in zip(loop, ker):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_kernel_reschedule_duplicate_clients_tie_break():
    """All-ties federation through the kernel path: first-minimum order."""
    counts = np.tile(np.array([[3.0, 1.0, 0.0]]), (7, 1))
    ker = scheduling.reschedule(counts, gamma=3, impl="batched",
                                use_kernel=True)
    assert [m.clients for m in ker] == [[0, 1, 2], [3, 4, 5], [6]]


def test_reschedule_empty_federation():
    for use_kernel in (False, True):
        assert scheduling.reschedule(np.zeros((0, 4)), gamma=2,
                                     use_kernel=use_kernel) == []


def test_reschedule_rejects_unknown_impl():
    with pytest.raises(ValueError, match="impl"):
        scheduling.reschedule(np.ones((4, 2)), gamma=2, impl="vectorized")


def test_place_mediators_stats_match_bruteforce_recount():
    """The reported local/cross-shard fetch counts must equal a from-
    scratch recount of the placement on a seeded federation schedule."""
    rng = np.random.default_rng(11)
    num_clients, num_shards, gamma = 32, 4, 3
    k_local = num_clients // num_shards
    owner = lambda cid: cid // k_local
    counts = _random_counts(rng, k=num_clients, c=10)
    sel = rng.choice(num_clients, size=24, replace=False)
    meds = scheduling.reschedule(counts[sel], gamma)
    groups = [[int(sel[i]) for i in m.clients] for m in meds]
    rows_per_shard = (len(groups) + num_shards - 1) // num_shards
    rows, stats = scheduling.place_mediators(groups, num_shards,
                                             rows_per_shard, owner)
    # brute-force recount: shard of a group = shard of its assigned row
    local = remote = 0
    seen = set()
    for r, g in enumerate(rows):
        if g < 0:
            continue
        assert g not in seen
        seen.add(g)
        shard = r // rows_per_shard
        for cid in groups[g]:
            if owner(cid) == shard:
                local += 1
            else:
                remote += 1
    assert seen == set(range(len(groups)))
    assert stats["local_fetches"] == local
    assert stats["remote_fetches"] == remote
    assert stats["total_fetches"] == local + remote == \
        sum(len(g) for g in groups)


@given(st.integers(0, 100), st.integers(8, 24), st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_greedy_reschedule_no_worse_than_random(seed, k, gamma):
    """Property (Fig. 7 mechanism): on skewed label histograms the greedy
    Alg. 3 schedule's mean mediator KLD never exceeds arbitrary random
    grouping of the same clients."""
    rng = np.random.default_rng(seed)
    counts = _random_counts(rng, k=k, c=8, skew=True)
    greedy = scheduling.schedule_stats(scheduling.reschedule(counts, gamma))
    rand = scheduling.schedule_stats(
        scheduling.random_schedule(k, gamma, counts, seed=seed))
    assert greedy["kld_mean"] <= rand["kld_mean"] + 1e-9
