"""The obs subsystem's contract (src/repro/obs + launch/metrics_endpoint).

The non-negotiable invariant: telemetry is measurement, never treatment.
Turning it on must leave trajectories bitwise identical, add zero round
traces, and keep every ledger equality exact -- the Prometheus WAN sample
IS ``CommMeter.total_bytes``, the wave span charges sum to the paper's
per-round formulas. These tests pin that contract for every client-store
placement policy, sync and async.
"""
import json
import math
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.launch.mesh import make_mediator_mesh
from repro.launch.metrics_endpoint import CONTENT_TYPE, MetricsServer
from repro.models.cnn import count_params, emnist_cnn
from repro.obs import (NULL_TELEMETRY, SCHEMA_VERSION, Telemetry, Tracer,
                       load_jsonl, validate_events)
from repro.obs.metrics import Counter, Histogram, MetricsRegistry
from repro.optim import adam

C, GAMMA, EM = 6, 3, 1


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _trainer(model, fed, store, s_bound, telemetry):
    kw = {}
    if s_bound is not None:
        from repro.core.async_engine import AsyncSpec
        from repro.core.staleness import StragglerSpec
        kw["async_spec"] = AsyncSpec(
            staleness_bound=s_bound, wave_size=1,
            straggler=StragglerSpec(model="fixed", straggler_frac=0.25,
                                    slowdown=4.0, seed=0))
    return AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=C,
                          gamma=GAMMA, local=LocalSpec(10, EM), alpha=None,
                          seed=0, store=store, mesh=make_mediator_mesh(1),
                          telemetry=telemetry, **kw)


def _run(model, fed, store, s_bound, telemetry, rounds=2):
    tr = _trainer(model, fed, store, s_bound, telemetry)
    for _ in range(rounds):
        tr.run_round()
    if s_bound is not None:
        tr.runner.flush()
    return tr


# ----------------------------------------------------------------------
# The invariant: tracing on == tracing off, bit for bit
# ----------------------------------------------------------------------

@pytest.mark.parametrize("store", ["replicated", "sharded", "host"])
@pytest.mark.parametrize("s_bound", [None, 0, 1],
                         ids=["sync", "asyncS0", "asyncS1"])
def test_telemetry_is_bitwise_invisible(model, tiny_federation, tmp_path,
                                        store, s_bound):
    """Same store policy, same engine mode: the traced run's parameter
    trajectory, WAN ledger and trace count must equal the untraced run's
    exactly -- telemetry lives entirely outside jit and outside the RNG
    draw order."""
    off = _run(model, tiny_federation, store, s_bound, None)
    tel = Telemetry(str(tmp_path / "t"))
    on = _run(model, tiny_federation, store, s_bound, tel)

    for a, b in zip(jax.tree.leaves(off.params), jax.tree.leaves(on.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert off.comm.total_bytes == on.comm.total_bytes
    assert off.engine.num_round_traces == on.engine.num_round_traces == 1
    # tracing adds ZERO retraces: every logged trace is an initial compile
    assert all(t["reason"] == "initial" for t in on.engine.trace_log)
    # and the artifacts actually materialized on the traced side
    paths = tel.flush()
    validate_events(load_jsonl(paths["events_jsonl"]))


def test_telemetry_defaults_to_noop_stubs(model, tiny_federation):
    """telemetry=None threads the shared NULL_TELEMETRY singleton through
    engine and store -- the off path allocates nothing per round."""
    tr = _trainer(model, tiny_federation, "replicated", None, None)
    assert tr.engine.telemetry is NULL_TELEMETRY
    assert tr.engine.store.telemetry is NULL_TELEMETRY
    assert not NULL_TELEMETRY.enabled
    sp = NULL_TELEMETRY.span("round", anything=1)
    with sp as s:
        assert s.set(x=2) is s and s.sync_on(object()) is s
    assert NULL_TELEMETRY.flush() == {}


# ----------------------------------------------------------------------
# Span stream: schema, nesting, taxonomy, zero-retrace
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_sync(model, tiny_federation, tmp_path_factory):
    tel = Telemetry(str(tmp_path_factory.mktemp("obs") / "sync"))
    tr = _run(model, tiny_federation, "replicated", None, tel, rounds=3)
    return tr, tel, tel.flush()


@pytest.fixture(scope="module")
def traced_async(model, tiny_federation, tmp_path_factory):
    tel = Telemetry(str(tmp_path_factory.mktemp("obs") / "async"))
    tr = _run(model, tiny_federation, "replicated", 1, tel, rounds=3)
    return tr, tel, tel.flush()


def test_jsonl_schema_and_nesting(traced_sync):
    tr, tel, paths = traced_sync
    events = load_jsonl(paths["events_jsonl"])
    validate_events(events)          # keys, schema version, parent nesting
    assert events and all(e["schema"] == SCHEMA_VERSION for e in events)
    names = {e["name"] for e in events}
    assert {"round", "pack", "reschedule", "store_stream",
            "aggregate"} <= names
    # one round span per round, each a root (no parent)
    rounds = [e for e in events if e["name"] == "round"]
    assert len(rounds) == 3
    assert all(e["parent"] is None for e in rounds)
    # pack/aggregate spans nest under a round span
    rids = {e["id"] for e in rounds}
    for e in events:
        if e["name"] in ("pack", "aggregate"):
            assert e["parent"] in rids


def test_round_traces_stay_one_under_tracing(traced_sync):
    tr, _, _ = traced_sync
    assert tr.engine.num_round_traces == 1
    assert tr.engine.trace_log == [{"fn": "round_fn", "round": 0,
                                    "trace_index": 1, "reason": "initial"}]


def test_chrome_trace_is_perfetto_loadable(traced_sync):
    _, tel, paths = traced_sync
    with open(paths["trace_json"]) as f:
        chrome = json.load(f)
    assert isinstance(chrome["traceEvents"], list)
    assert len(chrome["traceEvents"]) == len(tel.tracer.events)
    for ev in chrome["traceEvents"]:
        assert ev["ph"] in ("X", "i")
        assert ev["ts"] >= 0 and ev["dur"] >= 0


def test_wave_charges_sum_to_round_formula(traced_async):
    """Async S=1: every round span's wan_bytes equals the sum of its wave
    spans' charges AND the paper's per-round formula
    2|w|(c E_m + ceil(c/gamma)) -- the spans are the ledger, re-keyed."""
    tr, tel, _ = traced_async
    events = tel.tracer.events
    w = count_params(tr.params) * 4
    per_round = 2 * w * (C * EM + math.ceil(C / GAMMA))
    rounds = [e for e in events if e["name"] == "round"]
    assert len(rounds) == 3
    for rspan in rounds:
        waves = [e for e in events
                 if e["name"] == "wave" and e["parent"] == rspan["id"]]
        assert waves, "async rounds execute at least one wave"
        wave_sum = sum(e["attrs"]["wan_bytes"] for e in waves)
        assert wave_sum == rspan["attrs"]["wan_bytes"] == per_round
    assert sum(e["attrs"]["wan_bytes"] for e in rounds) == \
        tr.comm.total_bytes
    # commits carry the staleness the histogram absorbed
    commits = [e for e in events if e["name"] == "commit"]
    assert commits and all(e["attrs"]["staleness_max"] <= 1 for e in commits)


# ----------------------------------------------------------------------
# Metrics registry: ledgers mirrored exactly, Prometheus + endpoint
# ----------------------------------------------------------------------

def test_prometheus_wan_equals_comm_ledger(traced_sync):
    tr, tel, paths = traced_sync
    prom = tel.metrics.to_prometheus()
    sample = {line.split()[0]: float(line.split()[1])
              for line in prom.splitlines() if not line.startswith("#")
              and "{" not in line}
    assert sample["astraea_wan_bytes_total"] == tr.comm.total_bytes
    assert sample["astraea_rounds_total"] == 3
    assert sample["astraea_round_traces"] == 1
    assert sample["astraea_unexpected_retraces"] == 0
    with open(paths["metrics_prom"]) as f:
        assert f.read() == prom


def test_metrics_jsonl_has_one_row_per_round(traced_sync):
    _, tel, paths = traced_sync
    rows = load_jsonl(paths["metrics_jsonl"])
    assert [r["round"] for r in rows] == [1, 2, 3]
    # cumulative counters never decrease across the round timeline
    for a, b in zip(rows, rows[1:]):
        assert b["astraea_wan_bytes_total"] >= a["astraea_wan_bytes_total"]


def test_staleness_histogram_absorbed(traced_async):
    tr, tel, _ = traced_async
    snap = tel.metrics.snapshot()
    hist = snap["astraea_staleness"]
    total_contrib = sum(len(e["staleness"]) for e in tr.runner.commit_log)
    assert hist["count"] == total_contrib
    assert hist["le_inf"] == total_contrib
    assert snap["astraea_commits_total"] == tr.runner.num_commits


def test_metrics_endpoint_scrape(traced_sync):
    """A live GET /metrics serves the registry's exposition with the
    Prometheus content type; other paths 404."""
    tr, tel, _ = traced_sync
    with MetricsServer(tel.metrics) as srv:
        resp = urllib.request.urlopen(srv.url, timeout=10)
        assert resp.headers["Content-Type"] == CONTENT_TYPE
        body = resp.read().decode()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{srv.host}:{srv.port}/other", timeout=10)
    wan = [l for l in body.splitlines()
           if l.startswith("astraea_wan_bytes_total ")]
    assert wan and float(wan[0].split()[1]) == tr.comm.total_bytes


# ----------------------------------------------------------------------
# Unified ClientStore.stats() schema (satellite)
# ----------------------------------------------------------------------

def test_store_stats_schema_is_policy_invariant(model, tiny_federation):
    """Every placement policy answers stats() with the same key set --
    the registry mirrors them without per-policy branching."""
    key_sets = {}
    for store in ("replicated", "sharded", "host"):
        tr = _trainer(model, tiny_federation, store, None, None)
        stats = tr.engine.store.stats()
        assert stats["policy"] == store
        key_sets[store] = frozenset(stats)
    assert len(set(key_sets.values())) == 1, key_sets


# ----------------------------------------------------------------------
# Unit coverage: tracer + registry primitives
# ----------------------------------------------------------------------

def test_tracer_deterministic_with_fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(clock=clock)
    with tr.span("round", round=0) as r:
        tr.instant("charge", bytes=8)
        with tr.span("pack") as p:
            p.set(m_pad=4)
    validate_events(tr.events)
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["pack"]["parent"] == r.span_id
    assert by_name["charge"]["parent"] == r.span_id
    assert by_name["pack"]["attrs"] == {"m_pad": 4}
    assert by_name["round"]["dur_us"] > by_name["pack"]["dur_us"]


def test_validate_events_rejects_escaped_child():
    bad = [
        {"schema": SCHEMA_VERSION, "kind": "span", "id": 0, "parent": None,
         "name": "round", "ts_us": 0.0, "dur_us": 10.0, "attrs": {}},
        {"schema": SCHEMA_VERSION, "kind": "span", "id": 1, "parent": 0,
         "name": "pack", "ts_us": 5.0, "dur_us": 10.0, "attrs": {}},
    ]
    with pytest.raises(ValueError, match="escapes parent"):
        validate_events(bad)
    with pytest.raises(ValueError, match="missing keys"):
        validate_events([{"schema": SCHEMA_VERSION}])


def test_counter_set_total_is_monotone():
    c = Counter("x", "")
    c.set_total(10)
    c.inc(5)
    assert c.sample() == 15
    with pytest.raises(ValueError):
        c.set_total(3)


def test_histogram_buckets_are_cumulative():
    h = Histogram("h", buckets=(1, 2, 4))
    for v in (0.5, 1.5, 3, 100):
        h.observe(v)
    s = h.sample()
    assert (s["le_1"], s["le_2"], s["le_4"], s["le_inf"]) == (1, 2, 3, 4)
    assert s["count"] == 4 and s["sum"] == pytest.approx(105.0)


def test_prometheus_exposition_shape():
    reg = MetricsRegistry()
    reg.counter("astraea_wan_bytes_total", "wan").set_total(1024)
    reg.histogram("astraea_staleness", (0, 1)).observe(1)
    text = reg.to_prometheus()
    assert "# TYPE astraea_wan_bytes_total counter" in text
    assert "astraea_wan_bytes_total 1024" in text
    assert 'astraea_staleness_bucket{le="+Inf"} 1' in text
    assert "astraea_staleness_count 1" in text
    with pytest.raises(TypeError):
        reg.gauge("astraea_wan_bytes_total")   # kind collision
