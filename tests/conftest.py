"""Shared test fixtures. NOTE: no XLA_FLAGS here -- smoke tests must see
exactly 1 CPU device (only launch/dryrun.py forces 512 placeholders).

If ``hypothesis`` is missing (optional dev dep, see requirements-dev.txt) we
install a minimal fallback into ``sys.modules`` BEFORE the property-test
modules import it: deterministic random sampling from the same strategy
surface the suite uses (integers/floats/sampled_from/lists). Property tests
then still run -- with fewer, seeded examples -- instead of erroring the
whole collection."""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    _FALLBACK_EXAMPLES = int(os.environ.get("FALLBACK_HYPOTHESIS_EXAMPLES", "5"))

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self.example(rng)).example(rng))

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.example(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self.example(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")
            return _Strategy(draw)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elem, min_size=0, max_size=None):
        def draw(rng):
            hi = max_size if max_size is not None else min_size + 5
            n = rng.randint(min_size, hi)
            return [elem.example(rng) for _ in range(n)]
        return _Strategy(draw)

    def _given(*strats, **kwstrats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                        _FALLBACK_EXAMPLES)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    ex = [s.example(rng) for s in strats]
                    kw = {k: s.example(rng) for k, s in kwstrats.items()}
                    fn(*args, *ex, **kw, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            # @settings may sit either above or below @given
            if hasattr(fn, "_max_examples"):
                wrapper._max_examples = fn._max_examples
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def _settings(max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda cond: None
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.lists = _lists
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_federation():
    """A small LTRF-style federation reused across FL tests."""
    from repro.data.federated import partition, EMNIST_LIKE
    import dataclasses
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    return partition(spec, num_clients=12, total_samples=600, test_samples=160,
                     sizes="instagram", global_dist="letterfreq", local="random",
                     seed=0, name="tiny-ltrf")
