"""Shared test fixtures. NOTE: no XLA_FLAGS here -- smoke tests must see
exactly 1 CPU device (only launch/dryrun.py forces 512 placeholders)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_federation():
    """A small LTRF-style federation reused across FL tests."""
    from repro.data.federated import partition, EMNIST_LIKE
    import dataclasses
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    return partition(spec, num_clients=12, total_samples=600, test_samples=160,
                     sizes="instagram", global_dist="letterfreq", local="random",
                     seed=0, name="tiny-ltrf")
