"""2-D (mediator, model) mesh: tensor-sharded per-mediator model residency.

The contract under test (core/engine.py §8 + launch/mesh.py:make_fl_mesh):

* params shard over the ``model`` axis via the logical-axis rule tables
  and stay replicated over ``mediator``; client batches/schedules
  partition over ``mediator`` and never over ``model``;
* the model-axis gather/compute/reshard cycle moves exact bytes, so the
  ``2x2`` mesh trajectory is bitwise identical to ``4x1`` (and to the 1-D
  mediator mesh) for all three client stores under ``row_exec="map"``,
  sync AND async (S=0), with ``num_round_traces == 1`` throughout;
* per-device param bytes shrink by the model-axis factor, audited through
  ``ClientStore.stats()`` and real shard inspection;
* ``model=1`` reproduces today's 1-D trajectories bitwise.

The 4-device subprocess mirrors tests/test_client_store.py: the device
count must be forced before jax initializes.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import LocalSpec, augmentation
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.launch.mesh import (default_fl_mesh, make_fl_mesh,
                               make_mediator_mesh, model_axis_size)
from repro.launch.sharding import (model_only_rules, param_shardings,
                                   spec_for, TRAIN_RULES)
from repro.models.cnn import cinic_cnn, emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_make_fl_mesh_shapes_and_validation():
    mesh = make_fl_mesh(mediator=1, model=1)
    assert dict(mesh.shape) == {"mediator": 1, "model": 1}
    assert model_axis_size(mesh) == 1
    assert model_axis_size(make_mediator_mesh(1)) == 1
    with pytest.raises(ValueError, match="model axis"):
        make_fl_mesh(mediator=1, model=0)
    # a model axis the device count cannot host is rejected (nd+1 never
    # divides nd, so this holds on the 1-device container AND the forced
    # 4-device CI legs)
    nd = len(jax.devices())
    with pytest.raises(ValueError, match="divisible"):
        make_fl_mesh(model=nd + 1)
    # default_fl_mesh(1) keeps the 1-D mediator mesh (today's programs)
    assert tuple(default_fl_mesh(1).axis_names) == ("mediator",)
    if nd % 2 == 0:
        mesh2 = default_fl_mesh(2)
        assert dict(mesh2.shape) == {"mediator": nd // 2, "model": 2}


def test_cnn_param_specs_mirror_init(tiny_federation):
    """Both CNNs carry logical-axis spec trees matching their init output
    (structure AND shapes), so param_shardings can place them."""
    for m in (emnist_cnn(tiny_federation.num_classes, image_size=16),
              cinic_cnn(8, image_size=16, width=8)):
        params = m.init(jax.random.PRNGKey(0))
        specs = m.param_specs()
        flat_p, tree_p = jax.tree.flatten(params)
        flat_s, tree_s = jax.tree.flatten(
            specs, is_leaf=lambda x: hasattr(x, "axes"))
        assert tree_p == tree_s
        for p, s in zip(flat_p, flat_s):
            assert p.shape == s.shape, s.axes


def test_rule_tables_shard_wide_dims_over_model_only(tiny_federation):
    """spec_for on the FL mesh: output-channel / feature dims ride the
    ``model`` axis, contraction dims and the mediator axis never shard."""
    mesh = make_fl_mesh(mediator=1, model=1)
    model = emnist_cnn(8, image_size=16)
    shardings = param_shardings(model.param_specs(), mesh, model_only_rules())
    specs = {k: {n: s.spec for n, s in v.items()}
             for k, v in shardings.items()}
    assert specs["conv1"]["w"] == P(None, None, None, "model")
    assert specs["dense1"]["w"] == P(None, "model")
    assert specs["out"]["w"] == P(None, "model")      # nc=8 divides
    for leaf in jax.tree.leaves(shardings,
                                is_leaf=lambda x: hasattr(x, "spec")):
        assert "mediator" not in tuple(leaf.spec)     # never over mediator
    # a dim a bigger model axis does not divide falls back to replicated:
    # spec_for on a device-free abstract 2-way model axis
    from repro.launch.compat import abstract_mesh
    am = abstract_mesh((1, 2), ("mediator", "model"))
    assert spec_for((47,), ("vocab",), am, model_only_rules()) == P()
    assert spec_for((48,), ("vocab",), am, model_only_rules()) == P("model")


def test_engine_2d_one_device_mesh_bitwise_matches_1d(model,
                                                      tiny_federation):
    """A (1,1) 2-D mesh reproduces the 1-D mediator mesh bitwise, aug on,
    across a reschedule, with one trace -- the model=1 degenerate case."""
    plan = augmentation.augmentation_plan(
        tiny_federation.client_counts().sum(0), 0.67)
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0,
                               pad_mediators_to=2,
                               reschedule_every_round=True)

    def run(mesh):
        e = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                          mesh=mesh, aug_plan=plan)
        e.run_round()
        e.run_round()
        return e

    e2d = run(make_fl_mesh(mediator=1, model=1))
    e1d = run(make_mediator_mesh(1))
    _params_equal(e2d.params, e1d.params)
    assert e2d.num_round_traces == 1
    st = e2d.store.stats()
    assert st["model_axis"] == 1
    assert st["per_device_param_bytes"] == \
        e1d.store.stats()["per_device_param_bytes"]
    # model=1: no intra-pod collectives, identical WAN ledger
    assert e2d.comm.intra_pod_bytes == 0
    assert e2d.comm.total_bytes == e1d.comm.total_bytes


def test_trainer_model_parallel_knob(model, tiny_federation):
    """The trainer surface: model_parallel picks the mesh, an impossible
    factor is rejected, and the knob is ignored when a mesh is given."""
    from repro.core.astraea import AstraeaTrainer
    from repro.core.fedavg import FedAvgTrainer
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
                        alpha=None, seed=0, model_parallel=1)
    assert tuple(tr.engine.mesh.axis_names) == ("mediator",)
    tr.run_round()
    bad = len(jax.devices()) + 1        # nd+1 never divides nd
    with pytest.raises(ValueError, match="divisible"):
        AstraeaTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
                       alpha=None, seed=0, model_parallel=bad)
    with pytest.raises(ValueError, match="divisible"):
        FedAvgTrainer(model, adam(1e-3), tiny_federation,
                      clients_per_round=4, local=LocalSpec(10, 1),
                      seed=0, model_parallel=bad)
    # explicit mesh wins over the knob
    fa = FedAvgTrainer(model, adam(1e-3), tiny_federation,
                       clients_per_round=4, local=LocalSpec(10, 1), seed=0,
                       mesh=make_mediator_mesh(1), model_parallel=None)
    fa.run_round()
    assert fa.engine.num_round_traces == 1


def test_model_unannotated_falls_back_to_replicated(tiny_federation):
    """A Model without param_specs still runs on a 2-D mesh -- params stay
    replicated along model (no residency win, no crash)."""
    m = dataclasses.replace(emnist_cnn(8, image_size=16), param_specs=None)
    eng = FLRoundEngine(
        m, adam(1e-3), tiny_federation,
        EngineConfig.astraea(clients_per_round=4, gamma=2,
                             local=LocalSpec(10, 1), seed=0),
        mesh=make_fl_mesh(mediator=1, model=1))
    eng.run_round()
    assert eng._param_shardings is None


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.pop("ASTRAEA_MODEL_PARALLEL", None)
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec, augmentation
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.staleness import StragglerSpec
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_fl_mesh, make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)
    plan = augmentation.augmentation_plan(fed.client_counts().sum(0), 0.67)
    base = EngineConfig.astraea(clients_per_round=6, gamma=3,
                                local=LocalSpec(10, 1), seed=0,
                                pad_mediators_to=4, row_exec="map",
                                donate_params=False,
                                reschedule_every_round=True)
    m22 = make_fl_mesh(mediator=2, model=2)
    m41 = make_fl_mesh(mediator=4, model=1)

    def run(mesh, store, async_spec=None):
        cfg = dataclasses.replace(base, store=store)
        e = FLRoundEngine(model, adam(1e-3), fed, cfg, mesh=mesh,
                          aug_plan=plan)
        r = e if async_spec is None else AsyncRoundEngine(e, async_spec)
        r.run_round()
        r.run_round()
        return r

    def check(a, b, tag):
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=tag)

    # (a) 2x2 == 4x1 bitwise for ALL THREE stores (online aug riding along)
    runs = {}
    for store in ("replicated", "sharded", "host"):
        e22, e41 = run(m22, store), run(m41, store)
        check(e22, e41, store)
        assert e22.num_round_traces == 1 and e41.num_round_traces == 1
        assert e22.num_schedule_packs == 2
        runs[store] = (e22, e41)
    # ... and 4x1 == today's 1-D mediator mesh (model=1 reproduction claim)
    check(run(make_mediator_mesh(4), "replicated"), runs["replicated"][1],
          "2d-vs-1d")

    # (b) per-device param bytes shrink by the model-axis factor, via
    # ClientStore.stats() AND real addressable-shard inspection
    e22, e41 = runs["replicated"]
    s22, s41 = e22.store.stats(), e41.store.stats()
    assert s22["model_axis"] == 2 and s41["model_axis"] == 1
    assert s22["per_device_param_bytes"] * 2 == s41["per_device_param_bytes"]
    for leaf in jax.tree.leaves(e22.params):
        shards = leaf.addressable_shards
        assert len(shards) == 4
        # every emnist leaf dim the rules shard divides by 2: each device
        # holds exactly half the leaf (replicated over mediator rows)
        assert all(s.data.nbytes * 2 == leaf.nbytes for s in shards)
    for leaf in jax.tree.leaves(e41.params):
        assert all(s.data.nbytes == leaf.nbytes
                   for s in leaf.addressable_shards)
    # the client axis partitions over the mediator submesh rows (2 on the
    # 2x2 mesh), never over model
    assert runs["sharded"][0].store.per_device_bytes() * 2 == \\
        runs["replicated"][0].store.per_device_bytes()

    # (c) async S=0 on the 2-D mesh: bitwise-sync, one trace, aug on
    aspec = AsyncSpec(staleness_bound=0, wave_size=1,
                      straggler=StragglerSpec(model="fixed", seed=0))
    a22 = run(m22, "replicated", aspec)
    check(a22, e22, "async-s0-2x2")
    assert a22.engine.num_round_traces == 1

    # (d) ledger split: model parallelism charges intra-pod bytes only --
    # the WAN ledger is invariant to the server's model-parallel layout
    assert e22.comm.total_bytes == e41.comm.total_bytes
    assert e22.comm.intra_pod_bytes > 0 and e41.comm.intra_pod_bytes == 0
    print("OK")
""")


def test_2d_mesh_multi_device(tmp_path):
    """The ISSUE-5 acceptance claims on a real 4-device mesh (subprocess:
    the device count must be forced before jax initializes)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
