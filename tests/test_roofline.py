"""HLO cost parser: verified against a hand-checkable compiled program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import parse_hlo_costs, compiled_costs
from repro.roofline.model import (roofline_from_costs, HW, kernel_roofline,
                                  achieved_fraction)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    c = _compile(f, w, x)
    costs = parse_hlo_costs(c.as_text())
    expect = 8 * 2 * 32 * 256 * 256          # trips x dot flops
    assert costs.flops == pytest.approx(expect, rel=0.05)
    assert 8 in costs.while_trips.values()


def test_single_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    c = _compile(f, a, b)
    costs = parse_hlo_costs(c.as_text())
    assert costs.flops == pytest.approx(2 * 128 * 512 * 64, rel=0.01)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(h, _):
            def inner(h2, wl):
                return jnp.tanh(h2 @ wl), None
            h2, _ = jax.lax.scan(inner, h, w)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()

    w = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    c = _compile(f, w, x)
    costs = parse_hlo_costs(c.as_text())
    expect = 3 * 4 * 2 * 16 * 128 * 128
    assert costs.flops == pytest.approx(expect, rel=0.05)


def test_bytes_accessed_reasonable():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    b = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(f, a, b)
    costs = parse_hlo_costs(c.as_text())
    io = 3 * 1024 * 1024 * 4
    assert io * 0.9 <= costs.bytes_accessed <= io * 2.5


def test_roofline_terms_math():
    t = roofline_from_costs(flops=197e12, bytes_accessed=819e9,
                            collective_bytes=50e9, model_flops_total=100e12,
                            hw=HW())
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.useful_ratio == pytest.approx(100 / 197, rel=1e-3)
    assert t.dominant in ("compute", "memory", "collective")


def test_kernel_roofline_bound_selection():
    ridge = kernel_roofline(flops=197e12, bytes_accessed=819e9)
    assert ridge["compute_s"] == pytest.approx(1.0)
    assert ridge["memory_s"] == pytest.approx(1.0)
    assert ridge["roofline_s"] == pytest.approx(1.0)
    assert ridge["intensity"] == pytest.approx(ridge["ridge_intensity"],
                                               rel=1e-6)
    mem = kernel_roofline(flops=1e6, bytes_accessed=819e9)
    assert mem["bound"] == "memory"
    assert mem["roofline_s"] == pytest.approx(1.0)
    comp = kernel_roofline(flops=197e12, bytes_accessed=1e3)
    assert comp["bound"] == "compute"
    assert achieved_fraction(2.0, 1.0) == pytest.approx(0.5)
    assert achieved_fraction(0.0, 1.0) > 0  # measured=0 stays finite


def test_fedavg_agg_analytic_cost_terms():
    """The kernel's CostEstimate is exactly 2*M*N FLOPs against one delta
    read + one out write (+ the weight vector) -- and at fp32 it sits on
    the memory wall of the v5e roofline."""
    from repro.kernels import fedavg_agg as fa
    m, n = 16, 1 << 14
    est = fa.cost_estimate(m, n, 4, 4)
    assert est.flops == 2 * m * n
    assert est.transcendentals == 0
    assert est.bytes_accessed == m * n * 4 + n * 4 + m * 4
    assert kernel_roofline(est.flops, est.bytes_accessed)["bound"] == "memory"
    # bf16 deltas halve the dominant (delta-read) term
    bf = fa.cost_estimate(m, n, 2, 2)
    assert bf.bytes_accessed == m * n * 2 + n * 2 + m * 4
    assert bf.flops == est.flops


def test_kld_cost_models_compose():
    """greedy_cost(K, C) = K absorption sweeps of score_cost(1, K, C)
    compute with a K x (K, C) streaming byte ledger."""
    from repro.kernels import kld_score as kl
    k, c = 96, 47
    sweep = kl.score_cost(1, k, c)
    greedy = kl.greedy_cost(k, c)
    assert greedy.transcendentals == k * sweep.transcendentals
    assert greedy.flops == k * sweep.flops + 4 * k * k
    assert greedy.bytes_accessed == k * k * c * 4 + k * 4
    m = 8
    mat = kl.score_cost(m, k, c)
    assert mat.flops == m * sweep.flops
    assert mat.transcendentals == m * k * c


def test_fedavg_agg_analytic_matches_hlo_reference():
    """Cross-check: the analytic cost model vs the compiled XLA reference
    program (kernels.ref.fedavg_agg). FLOPs must agree tightly (one
    m,mn->n contraction); the analytic HBM bytes must be within ~2x of
    the post-fusion traffic of the reference program (one delta read
    dominates both)."""
    import jax.numpy as jnp
    from repro.kernels import fedavg_agg as fa
    from repro.kernels import ref

    m, n = 8, 4096
    est = fa.cost_estimate(m, n, 4, 4)
    costs = compiled_costs(
        ref.fedavg_agg,
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32))
    assert costs.flops == pytest.approx(est.flops, rel=0.25)
    assert costs.bytes_accessed == pytest.approx(est.bytes_accessed, rel=1.0)
    assert costs.collective_bytes == 0
