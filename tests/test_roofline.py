"""HLO cost parser: verified against a hand-checkable compiled program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import parse_hlo_costs
from repro.roofline.model import roofline_from_costs, HW


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    c = _compile(f, w, x)
    costs = parse_hlo_costs(c.as_text())
    expect = 8 * 2 * 32 * 256 * 256          # trips x dot flops
    assert costs.flops == pytest.approx(expect, rel=0.05)
    assert 8 in costs.while_trips.values()


def test_single_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    c = _compile(f, a, b)
    costs = parse_hlo_costs(c.as_text())
    assert costs.flops == pytest.approx(2 * 128 * 512 * 64, rel=0.01)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(h, _):
            def inner(h2, wl):
                return jnp.tanh(h2 @ wl), None
            h2, _ = jax.lax.scan(inner, h, w)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()

    w = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    c = _compile(f, w, x)
    costs = parse_hlo_costs(c.as_text())
    expect = 3 * 4 * 2 * 16 * 128 * 128
    assert costs.flops == pytest.approx(expect, rel=0.05)


def test_bytes_accessed_reasonable():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    b = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(f, a, b)
    costs = parse_hlo_costs(c.as_text())
    io = 3 * 1024 * 1024 * 4
    assert io * 0.9 <= costs.bytes_accessed <= io * 2.5


def test_roofline_terms_math():
    t = roofline_from_costs(flops=197e12, bytes_accessed=819e9,
                            collective_bytes=50e9, model_flops_total=100e12,
                            hw=HW())
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.useful_ratio == pytest.approx(100 / 197, rel=1e-3)
    assert t.dominant in ("compute", "memory", "collective")
