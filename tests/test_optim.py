"""Optimizer substrate: Adam/SGD math vs hand-computed references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, adamw, sgd, constant, cosine_decay, warmup_cosine
from repro.optim.optimizers import apply_updates, clip_by_global_norm


def test_sgd_step_exact():
    opt = sgd(0.1)
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.5, -1.0])}
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    new = apply_updates(params, updates)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.95, 2.1])


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.9)
    params = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    state = opt.init(params)
    u1, state = opt.update(g, state, params)
    u2, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(u1["w"]), [-1.0])
    np.testing.assert_allclose(np.asarray(u2["w"]), [-1.9])   # 0.9*1 + 1


def test_adam_first_step_is_lr_sized():
    """After bias correction, |first Adam update| == lr for any grad scale."""
    opt = adam(1e-3)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for scale in (1e-4, 1.0, 1e4):
        g = {"w": jnp.full(3, scale)}
        updates, _ = opt.update(g, state, params)
        np.testing.assert_allclose(np.abs(np.asarray(updates["w"])),
                                   1e-3, rtol=1e-3)


def test_adam_converges_on_quadratic():
    opt = adam(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_adamw_decays_weights():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.zeros(1)}, state, params)
    assert float(updates["w"][0]) < -0.4      # decay term dominates


def test_bf16_moments_roundtrip():
    opt = adam(1e-3, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state["adam"].mu["w"].dtype == jnp.bfloat16
    updates, state = opt.update({"w": jnp.ones(4, jnp.bfloat16)}, state, params)
    assert np.isfinite(np.asarray(updates["w"], np.float32)).all()


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped = clip_by_global_norm(grads, 1.0)                 # norm 5 -> 1
    total = np.sqrt(sum(float((g ** 2).sum()) for g in jax.tree.leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)
    unclipped = clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0])


def test_schedules():
    step = jnp.asarray(0)
    assert float(constant(0.5)(step)) == 0.5
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(wc(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
