"""Algorithm 2 tests: plan math, client rebalance, global-KLD reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import augmentation as aug
from repro.core import distribution as dist


def test_plan_majority_classes_not_augmented():
    counts = np.array([100, 90, 10, 5])
    plan = aug.augmentation_plan(counts, 0.67)
    c_bar = counts.mean()
    assert all(plan[counts >= c_bar] == 0)
    assert all(plan[counts < c_bar] > 0)


@given(st.floats(0.1, 1.0), st.floats(1.1, 3.0))
@settings(max_examples=25, deadline=None)
def test_plan_alpha_monotone(a_small, a_big):
    counts = np.array([1000, 500, 100, 20, 4])
    p_small = aug.augmentation_plan(counts, a_small)
    p_big = aug.augmentation_plan(counts, a_big)
    assert np.all(p_big >= p_small)


def test_alpha_two_overshoots():
    """The paper's failure mode: alpha=2 re-imbalances the dataset."""
    counts = np.array([1000.0, 500.0, 200.0, 50.0, 10.0])
    c_bar = counts.mean()
    good = aug.planned_counts(counts, 0.67)
    bad = aug.planned_counts(counts, 2.0)
    assert good.max() <= counts.max() * 1.01         # stays bounded
    assert bad[-1] > 10 * c_bar                      # minority explodes past mean
    kld_before = float(dist.kld_to_uniform(jnp.asarray(counts)))
    kld_good = float(dist.kld_to_uniform(jnp.asarray(good)))
    assert kld_good < kld_before


def test_random_affine_shapes_and_finite(key):
    img = jnp.ones((20, 20, 3))
    out = aug.random_affine(key, img)
    assert out.shape == img.shape
    assert np.isfinite(np.asarray(out)).all()


def test_rebalance_client_counts(key):
    rng = np.random.default_rng(0)
    images = rng.normal(size=(30, 12, 12, 1)).astype(np.float32)
    labels = np.array([0] * 20 + [1] * 8 + [2] * 2)
    plan = np.array([0, 2, 5])
    x, y = aug.rebalance_client(key, images, labels, plan)
    assert (y == 0).sum() == 20                       # untouched
    assert (y == 1).sum() == 8 * 3                    # 8 + 2 copies each
    assert (y == 2).sum() == 2 * 6                    # 2 + 5 copies each
    assert x.shape[0] == y.shape[0]


def test_rebalance_federation_reduces_global_kld(key, tiny_federation):
    fed = tiny_federation
    before = float(dist.kld_to_uniform(
        jnp.asarray(fed.client_counts().sum(0))))
    new_x, new_y, plan, extra = aug.rebalance_federation(
        key, fed.client_images, fed.client_labels, fed.num_classes, alpha=0.67)
    counts = np.zeros(fed.num_classes)
    for y in new_y:
        counts += np.bincount(y, minlength=fed.num_classes)
    after = float(dist.kld_to_uniform(jnp.asarray(counts)))
    assert after < before
    assert extra > 0
