"""Algorithm 2 tests: plan math, client rebalance, global-KLD reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import augmentation as aug
from repro.core import distribution as dist


def test_plan_majority_classes_not_augmented():
    counts = np.array([100, 90, 10, 5])
    plan = aug.augmentation_plan(counts, 0.67)
    c_bar = counts.mean()
    assert all(plan[counts >= c_bar] == 0)
    assert all(plan[counts < c_bar] > 0)


@given(st.floats(0.1, 1.0), st.floats(1.1, 3.0))
@settings(max_examples=25, deadline=None)
def test_plan_alpha_monotone(a_small, a_big):
    counts = np.array([1000, 500, 100, 20, 4])
    p_small = aug.augmentation_plan(counts, a_small)
    p_big = aug.augmentation_plan(counts, a_big)
    assert np.all(p_big >= p_small)


def test_alpha_two_overshoots():
    """The paper's failure mode: alpha=2 re-imbalances the dataset."""
    counts = np.array([1000.0, 500.0, 200.0, 50.0, 10.0])
    c_bar = counts.mean()
    good = aug.planned_counts(counts, 0.67)
    bad = aug.planned_counts(counts, 2.0)
    assert good.max() <= counts.max() * 1.01         # stays bounded
    assert bad[-1] > 10 * c_bar                      # minority explodes past mean
    kld_before = float(dist.kld_to_uniform(jnp.asarray(counts)))
    kld_good = float(dist.kld_to_uniform(jnp.asarray(good)))
    assert kld_good < kld_before


def test_random_affine_shapes_and_finite(key):
    img = jnp.ones((20, 20, 3))
    out = aug.random_affine(key, img)
    assert out.shape == img.shape
    assert np.isfinite(np.asarray(out)).all()


def test_rebalance_client_counts(key):
    rng = np.random.default_rng(0)
    images = rng.normal(size=(30, 12, 12, 1)).astype(np.float32)
    labels = np.array([0] * 20 + [1] * 8 + [2] * 2)
    plan = np.array([0, 2, 5])
    x, y = aug.rebalance_client(key, images, labels, plan)
    assert (y == 0).sum() == 20                       # untouched
    assert (y == 1).sum() == 8 * 3                    # 8 + 2 copies each
    assert (y == 2).sum() == 2 * 6                    # 2 + 5 copies each
    assert x.shape[0] == y.shape[0]


def test_plan_empty_class_explicit():
    """Alg. 2 line 3 edge case: an empty class is below the mean (it joins
    the augmentation set) but holds nothing to warp -- its plan entry must
    be 0 by construction, while C_bar still averages over ALL classes."""
    counts = np.array([0, 100, 90, 10, 0])
    plan = aug.augmentation_plan(counts, 0.67)
    assert plan[0] == 0 and plan[4] == 0                 # empty: explicit 0
    assert plan[3] > 0                                   # minority: augmented
    c_bar = counts.mean()                                # 40, over 5 classes
    assert all(plan[(counts >= c_bar)] == 0)
    # planned counts keep empty classes empty -- augmentation cannot invent
    # samples for a class nobody holds
    planned = aug.planned_counts(counts, 0.67)
    assert planned[0] == 0 and planned[4] == 0
    # all-empty federation degenerates to the zero plan, not an error
    assert np.all(aug.augmentation_plan(np.zeros(4), 0.67) == 0)
    with pytest.raises(ValueError, match="1-D"):
        aug.augmentation_plan(np.zeros((2, 2)), 0.67)


def _mc_class_freqs(counts, alpha, *, n_batches=48, seed=0):
    """Monte Carlo class frequencies of online draws from a client whose
    local counts equal ``counts`` (pad = sum counts, all slots valid)."""
    counts = np.asarray(counts, int)
    labels = np.repeat(np.arange(counts.size), counts).astype(np.int32)
    rng = np.random.default_rng(seed)
    images = rng.normal(size=(labels.size, 6, 6, 1)).astype(np.float32)
    plan = jnp.asarray(aug.augmentation_plan(counts, alpha))
    mask = jnp.ones(labels.size, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_batches)
    fn = jax.jit(jax.vmap(lambda k: aug.online_augment_batch(
        k, jnp.asarray(images), jnp.asarray(labels), mask, plan)[1]))
    drawn = np.asarray(fn(keys)).ravel()
    return np.bincount(drawn, minlength=counts.size) / drawn.size


def test_online_expected_counts_match_planned(key):
    """The Alg. 2 consistency contract of online mode: the expected class
    mixture of the in-round draws is exactly planned_counts(counts, alpha)
    normalized (seeded Monte Carlo, tolerance ~3 sigma)."""
    counts = np.array([40, 20, 8, 4])
    alpha = 0.67
    freqs = _mc_class_freqs(counts, alpha)
    planned = aug.planned_counts(counts, alpha)
    expect = planned / planned.sum()
    np.testing.assert_allclose(freqs, expect, atol=0.03)
    np.testing.assert_allclose(np.asarray(aug.online_mixture(counts, alpha)),
                               expect)


def test_online_alpha_two_overshoot_reproduced():
    """The paper's alpha=2 failure mode, in ONLINE mode: the very-minority
    class overshoots past the mean and re-imbalances the drawn mixture."""
    counts = np.array([100, 50, 20, 5, 1])
    f_good = _mc_class_freqs(counts, 0.67, seed=1)
    f_bad = _mc_class_freqs(counts, 2.0, seed=1)
    # at alpha=2 the rarest class dominates the draws outright
    assert f_bad[-1] > 1.0 / counts.size            # overshot uniform share
    assert f_bad[-1] == f_bad.max()                 # ...and every class
    kld = lambda f: float(dist.kld_to_uniform(jnp.asarray(f * 1000.0)))
    assert kld(f_bad) > kld(f_good)                 # re-imbalanced


def test_online_zero_plan_is_pure_resample(key):
    """With an all-zero plan no draw is ever warped: every output slot is a
    bitwise copy of some input sample (determinism anchor for the engine's
    no-op guarantees)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(20, 6, 6, 1)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, 20).astype(np.int32))
    mask = jnp.ones(20, jnp.float32)
    ax, ay = aug.online_augment_batch(key, x, y, mask,
                                      jnp.zeros(3, jnp.int32))
    ax, ay = np.asarray(ax), np.asarray(ay)
    xs = np.asarray(x)
    for i in range(ax.shape[0]):
        src = np.flatnonzero((xs == ax[i]).all(axis=(1, 2, 3)))
        assert src.size >= 1 and np.asarray(y)[src[0]] == ay[i]


def test_online_dummy_slot_stays_noop(key):
    """An all-padding client (mask 0 everywhere) must not produce NaNs or
    out-of-range gathers -- the engine relies on masked no-ops."""
    x = jnp.ones((10, 6, 6, 1), jnp.float32)
    y = jnp.zeros(10, jnp.int32)
    ax, ay = aug.online_augment_batch(key, x, y, jnp.zeros(10, jnp.float32),
                                      jnp.asarray([3, 0], jnp.int32))
    assert np.isfinite(np.asarray(ax)).all()
    assert set(np.asarray(ay).tolist()) <= {0}


def test_rebalance_federation_reduces_global_kld(key, tiny_federation):
    fed = tiny_federation
    before = float(dist.kld_to_uniform(
        jnp.asarray(fed.client_counts().sum(0))))
    new_x, new_y, plan, extra = aug.rebalance_federation(
        key, fed.client_images, fed.client_labels, fed.num_classes, alpha=0.67)
    counts = np.zeros(fed.num_classes)
    for y in new_y:
        counts += np.bincount(y, minlength=fed.num_classes)
    after = float(dist.kld_to_uniform(jnp.asarray(counts)))
    assert after < before
    assert extra > 0
