"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced variant of the same family, runs one forward/train step on CPU with
shape + finiteness assertions. Plus the paper's CNNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import InputShape, make_batch
from repro.models import transformer as T
from repro.models.cnn import emnist_cnn, cinic_cnn, count_params

TRAIN = InputShape("t", 128, 2, "train")
PREFILL = InputShape("p", 128, 2, "prefill")


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(aid):
        if aid not in cache:
            cfg = C.reduced(C.get(aid))
            params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=256)
            cache[aid] = (cfg, params)
        return cache[aid]

    return get


@pytest.mark.parametrize("aid", C.ARCH_IDS)
def test_train_step_shapes_and_finite(aid, arch_state):
    cfg, params = arch_state(aid)
    batch = make_batch(cfg, TRAIN)["batch"]
    loss, metrics = jax.jit(lambda p, b: T.forward_train(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), aid
    # loss near ln(vocab) at init (sanity that logits are calibrated)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)
    # one SGD step decreases nothing structurally (grads finite)
    grads = jax.grad(lambda p: T.forward_train(p, cfg, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all(), aid


@pytest.mark.parametrize("aid", C.ARCH_IDS)
def test_prefill_then_decode(aid, arch_state):
    cfg, params = arch_state(aid)
    batch = make_batch(cfg, PREFILL)["batch"]
    logits, cache = jax.jit(lambda p, b: T.forward_prefill(p, cfg, b))(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    dec = {"tokens": jnp.zeros((2, 1), jnp.int32),
           "positions": jnp.full((2,), 128, jnp.int32)}
    if cfg.arch_type == "audio":
        dec["enc_out"] = jnp.ones((2, cfg.source_positions, cfg.d_model),
                                  cfg.np_dtype()) * 0.01
    dl, new_cache = jax.jit(lambda p, b, c: T.forward_decode(p, cfg, b, c))(
        params, dec, cache)
    assert dl.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(dl, np.float32)).all(), aid
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_decode_matches_forward_logits():
    """Teacher-forced decode must reproduce the train forward's next-token
    logits (cache correctness end-to-end, dense arch)."""
    cfg = C.reduced(C.get("qwen3-4b"))
    cfg = dataclasses.replace(cfg, remat=False)
    params = T.init_params(jax.random.PRNGKey(1), cfg, max_seq=64)
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab)
    # full forward logits at each position
    loss, _ = T.forward_train(params, cfg, {"tokens": toks, "labels": toks})
    prefill_logits, cache = T.forward_prefill(params, cfg, {"tokens": toks[:, :8]},
                                              pad_to=16)
    # decode tokens 8..15 one at a time
    outs = []
    for t in range(8, 16):
        dl, cache = T.forward_decode(
            params, cfg, {"tokens": toks[:, t:t + 1],
                          "positions": jnp.full((1,), t, jnp.int32)}, cache)
        outs.append(dl)
    # compare against prefill over the longer prefix
    full_logits, _ = T.forward_prefill(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(outs[-1][0, 0]),
                               np.asarray(full_logits[0, 0]), rtol=3e-2, atol=3e-2)


def test_sliding_window_decode_matches_ring_buffer():
    """SWA arch: decoding past the window uses the ring buffer correctly."""
    cfg = C.reduced(C.get("h2o-danube-1.8b"))
    cfg = dataclasses.replace(cfg, sliding_window=16, remat=False)
    params = T.init_params(jax.random.PRNGKey(3), cfg, max_seq=128)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 64), 0, cfg.vocab)
    _, cache = T.forward_prefill(params, cfg, {"tokens": toks[:, :48]})
    dl = None
    for t in range(48, 64):
        dl, cache = T.forward_decode(
            params, cfg, {"tokens": toks[:, t:t + 1],
                          "positions": jnp.full((1,), t, jnp.int32)}, cache)
    full_logits, _ = T.forward_prefill(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(dl[0, 0]), np.asarray(full_logits[0, 0]),
                               rtol=3e-2, atol=3e-2)


def test_emnist_cnn_param_count_matches_paper():
    model = emnist_cnn(47)
    params = model.init(jax.random.PRNGKey(0))
    assert count_params(params) == 68_873    # paper Section II-B


def test_cnn_forward_shapes(key):
    m = emnist_cnn(20)
    p = m.init(key)
    out = m.apply(p, jnp.zeros((3, 28, 28, 1)))
    assert out.shape == (3, 20)
    m2 = cinic_cnn(10)
    p2 = m2.init(key)
    out2 = m2.apply(p2, jnp.zeros((3, 32, 32, 3)), train=True, rngs=key)
    assert out2.shape == (3, 10)
    assert np.isfinite(np.asarray(out2)).all()


@pytest.mark.parametrize("aid,expect_billions", [
    ("grok-1-314b", 316.5), ("qwen1.5-110b", 111.2), ("mamba2-370m", 0.368),
    ("gemma-2b", 2.51), ("h2o-danube-1.8b", 1.83), ("whisper-base", 0.074),
    ("hymba-1.5b", 1.39), ("granite-moe-3b-a800m", 3.30), ("qwen3-4b", 4.02),
    ("internvl2-1b", 0.494),
])
def test_full_config_param_counts(aid, expect_billions):
    cfg = C.get(aid)
    n = T.param_count(cfg)
    assert n / 1e9 == pytest.approx(expect_billions, rel=0.02)


def test_granite_active_params_match_a800m():
    cfg = C.get("granite-moe-3b-a800m")
    assert T.active_param_count(cfg) / 1e9 == pytest.approx(0.88, rel=0.05)
