"""FLRoundEngine invariants (the device-resident sharded round program).

The references here deliberately re-implement the PRE-ENGINE trainer round:
host-side numpy repacking of (M, gamma, pad, ...) every round, vmap over
mediators, weighted_average aggregation -- exactly what
core/astraea.py and core/fedavg.py did before the engine refactor. The
engine must reproduce those trajectories from its packed-once device
buffers (bit-identically for the packing claim)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LocalSpec, scheduling
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fl import make_client_update, weighted_average
from repro.core.mediator import make_mediator_update
from repro.models.cnn import emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _pad_multiple(n, m):
    return ((n + m - 1) // m) * m


def _leaves_equal(a, b, assert_fn):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert_fn(np.asarray(x), np.asarray(y))


def _legacy_astraea_run(model, opt, data, *, c, gamma, local, mediator_epochs,
                        seed, rounds):
    """The pre-refactor AstraeaTrainer round loop: numpy repack per round."""
    sizes = [x.shape[0] for x in data.client_images]
    pad = _pad_multiple(max(sizes), local.batch_size)
    X, Y, MK = data.padded(pad)
    counts = data.client_counts()
    rng = np.random.default_rng(seed)
    params = model.init(jax.random.PRNGKey(seed))
    med_upd = make_mediator_update(model, opt, local, mediator_epochs)

    @jax.jit
    def round_fn(params, xs, ys, ms, keys):
        deltas = jax.vmap(med_upd, in_axes=(None, 0, 0, 0, 0))(
            params, xs, ys, ms, keys)
        delta = weighted_average(deltas, ms.sum(axis=(1, 2)))
        return jax.tree.map(lambda p, d: p + d, params, delta)

    sel = rng.choice(data.num_clients, size=c, replace=False)
    meds = scheduling.reschedule(counts[sel], gamma)
    groups = [[int(sel[i]) for i in m.clients] for m in meds]
    m_count = len(groups)
    for r in range(rounds):
        xs = np.zeros((m_count, gamma, pad) + X.shape[2:], np.float32)
        ys = np.zeros((m_count, gamma, pad), np.int32)
        ms = np.zeros((m_count, gamma, pad), np.float32)
        for mi, clients in enumerate(groups):
            for ci, cid in enumerate(clients):
                xs[mi, ci] = X[cid]
                ys[mi, ci] = Y[cid]
                ms[mi, ci] = MK[cid]
        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed + 1), r), m_count)
        params = round_fn(params, jnp.asarray(xs), jnp.asarray(ys),
                          jnp.asarray(ms), keys)
    return params


def _legacy_fedavg_run(model, opt, data, *, c, local, seed, rounds):
    """The pre-refactor FedAvgTrainer round loop."""
    sizes = [x.shape[0] for x in data.client_images]
    pad = _pad_multiple(max(sizes), local.batch_size)
    X, Y, MK = data.padded(pad)
    rng = np.random.default_rng(seed)
    params = model.init(jax.random.PRNGKey(seed))
    cli_upd = make_client_update(model, opt, local)

    @jax.jit
    def round_fn(params, xs, ys, masks, keys):
        ws = jax.vmap(cli_upd, in_axes=(None, 0, 0, 0, 0))(
            params, xs, ys, masks, keys)
        return weighted_average(ws, masks.sum(axis=(1,)))

    for r in range(rounds):
        sel = rng.choice(data.num_clients, size=c, replace=False)
        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed + 1), r), c)
        params = round_fn(params, jnp.asarray(X[sel]), jnp.asarray(Y[sel]),
                          jnp.asarray(MK[sel]), keys)
    return params


def test_packed_once_bit_identical_to_per_round_repacking(model,
                                                          tiny_federation):
    """(a) Device-resident gather plan == host numpy repacking, bitwise.

    Pinned to a 1-device mesh: the legacy reference is a single-device
    vmap, and XLA batched kernels are only bit-stable at a fixed batch
    width (multi-device equivalence is covered, with its own exactness
    story, in test_client_store.py)."""
    from repro.launch.mesh import make_mediator_mesh
    eng = FLRoundEngine(
        model, adam(1e-3), tiny_federation,
        EngineConfig.astraea(clients_per_round=6, gamma=3,
                             local=LocalSpec(10, 1), seed=0),
        mesh=make_mediator_mesh(1))
    for _ in range(2):
        eng.run_round()
    expect = _legacy_astraea_run(model, adam(1e-3), tiny_federation,
                                 c=6, gamma=3, local=LocalSpec(10, 1),
                                 mediator_epochs=1, seed=0, rounds=2)
    # packing happened once (one schedule), not once per round
    assert eng.num_schedule_packs == 1 and eng._round == 2
    _leaves_equal(eng.params, expect, np.testing.assert_array_equal)


def test_astraea_trainer_matches_pre_refactor_run(model, tiny_federation):
    """(b) Engine-backed AstraeaTrainer == pre-refactor trainer, 2 rounds
    (through the augmentation phase: the reference consumes tr.data).
    1-device mesh: the reference is a single-device vmap (see (a))."""
    from repro.core.astraea import AstraeaTrainer
    from repro.launch.mesh import make_mediator_mesh
    # materialized mode: the pre-refactor trainer augmented up front, so
    # the legacy reference (which consumes tr.data) needs the same path
    tr = AstraeaTrainer(model, adam(1e-3), tiny_federation,
                        clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
                        mediator_epochs=2, alpha=0.67,
                        aug_mode="materialized", seed=0,
                        mesh=make_mediator_mesh(1))
    tr.run_round()
    tr.run_round()
    expect = _legacy_astraea_run(model, adam(1e-3), tr.data,
                                 c=6, gamma=3, local=LocalSpec(10, 1),
                                 mediator_epochs=2, seed=0, rounds=2)
    _leaves_equal(
        tr.params, expect,
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6))
    assert tr.last_schedule_stats["num_mediators"] >= 2


def test_fedavg_is_gamma1_engine_config(model, tiny_federation):
    """(c) FedAvg == the gamma=1 singleton-schedule engine configuration."""
    from repro.launch.mesh import make_mediator_mesh
    cfg = EngineConfig.fedavg(clients_per_round=4, local=LocalSpec(10, 1),
                              seed=0)
    assert cfg.gamma == 1 and cfg.schedule == "random" \
        and cfg.aggregate == "weights"
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    for _ in range(2):
        eng.run_round()
    expect = _legacy_fedavg_run(model, adam(1e-3), tiny_federation,
                                c=4, local=LocalSpec(10, 1), seed=0, rounds=2)
    _leaves_equal(
        eng.params, expect,
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6))
    # FedAvg reschedules (and thus repacks its tiny gather plan) every round
    assert eng.num_schedule_packs == 2


@pytest.mark.parametrize("n", [1000, 4097])
def test_kernel_agg_matches_weighted_average_ragged(n):
    """(d) fedavg_agg on ragged N (not a block_n multiple) == Eq. 6."""
    from repro.kernels import ops as kops
    key = jax.random.PRNGKey(n)
    deltas = jax.random.normal(key, (5, n), jnp.float32)
    weights = jnp.asarray([3.0, 0.0, 1.5, 7.0, 0.25])
    out = kops.fedavg_agg(deltas, weights, block_n=256)
    expect = weighted_average(deltas, weights)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_engine_kernel_agg_path_matches_jnp(model, tiny_federation):
    """(d') the engine's kernel aggregation hot loop == the jnp path."""
    mk = lambda uk: FLRoundEngine(
        model, adam(1e-3), tiny_federation,
        EngineConfig.astraea(clients_per_round=4, gamma=2,
                             local=LocalSpec(10, 1), use_kernel_agg=uk,
                             seed=0))
    a, b = mk(False), mk(True)
    a.run_round()
    b.run_round()
    _leaves_equal(
        a.params, b.params,
        lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5))


def test_engine_reschedule_kernel_bitwise(model, tiny_federation):
    """(d'') reschedule_kernel routes Alg. 3 through the one-launch Pallas
    greedy pass; the schedule is bitwise-identical to the XLA scan path,
    so the whole trajectory must be too (not just allclose)."""
    mk = lambda rk: FLRoundEngine(
        model, adam(1e-3), tiny_federation,
        EngineConfig.astraea(clients_per_round=6, gamma=3,
                             local=LocalSpec(10, 1), reschedule_kernel=rk,
                             seed=0))
    a, b = mk(False), mk(True)
    for _ in range(2):
        a.run_round()
        b.run_round()
    _leaves_equal(a.params, b.params, np.testing.assert_array_equal)


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)
    cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                               local=LocalSpec(10, 1), seed=0)
    # pad_mediators_to=3 is NOT a multiple of the 4-device mesh: the
    # engine must round it up instead of handing shard_map a ragged M
    cfg4 = dataclasses.replace(cfg, pad_mediators_to=3)
    e4 = FLRoundEngine(model, adam(1e-3), fed, cfg4,
                       mesh=make_mediator_mesh(4))
    e1 = FLRoundEngine(model, adam(1e-3), fed, cfg,
                       mesh=make_mediator_mesh(1))
    e4.run_round()
    e1.run_round()
    diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(jax.tree.leaves(e4.params),
                               jax.tree.leaves(e1.params)))
    assert diff < 1e-5, diff
    print("OK", diff)
""")


def test_engine_multi_device_mediator_mesh(tmp_path):
    """(e) shard_map over a 4-device mediator mesh (dummy-mediator padding
    and mesh-rounding of pad_mediators_to included) matches the 1-device
    run. Subprocess: the device count must be forced before jax
    initializes."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=480)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
