"""Overlapped async dispatch, adaptive staleness, client-level stragglers.

The tentpole acceptance claims of the overlapped wave pipeline
(core/async_engine.py, "overlapped" dispatch):

* S=0 overlapped dispatch is BITWISE identical to the synchronous engine
  under the batch-size-invariant row executor (``row_exec="map"``), on 1
  and 4 forced host devices, across the replicated / sharded / spilled
  stores -- with one "initial" trace per wave width and ZERO retraces;
* the adaptive staleness controller (EWMA over observed commit lags)
  reproduces the fixed-S trajectory bitwise under constant lags, and its
  bound is monotone and clamped;
* client-level straggler factors co-schedule slow *devices* into late
  waves, and the all-unit-speed client model reproduces the historical
  mediator-level wave ordering bitwise;
* the spilled store's depth-N prefetch + LRU row cache never perturb
  trajectories (RNG draw order is preserved by the pre-draw deque);
* zero-round edge cases (flush before any round, ``fit(0)``) are no-ops.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import LocalSpec, scheduling
from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.staleness import (AdaptiveStaleness, AdaptiveStalenessSpec,
                                  StragglerModel, StragglerSpec)
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import emnist_cnn
from repro.optim import adam


@pytest.fixture(scope="module")
def model(tiny_federation):
    return emnist_cnn(tiny_federation.num_classes, image_size=16)


def _params_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _cfg(**kw):
    base = dict(clients_per_round=6, gamma=3, local=LocalSpec(10, 1),
                seed=0, pad_mediators_to=3, donate_params=False,
                reschedule_every_round=True, row_exec="map")
    base.update(kw)
    return EngineConfig.astraea(**base)


# ----------------------------------------------------------------------
# adaptive staleness controller
# ----------------------------------------------------------------------
def test_adaptive_ewma_monotone_toward_constant_lag():
    a = AdaptiveStaleness(AdaptiveStalenessSpec(s_min=0, s_max=8, beta=0.5))
    prev, bounds = a.ewma, []
    for _ in range(30):
        a.observe(3.0)
        assert prev < a.ewma <= 3.0      # monotone from below, never past
        prev = a.ewma
        bounds.append(a.bound)
    assert bounds == sorted(bounds)      # the derived bound is monotone too
    assert bounds[-1] == 3               # ceil of the converged estimate
    # and monotone from above when lags drop (enough steps for the decay
    # toward 1.0 to shrink past the bound's 1e-9 ceil tolerance; once the
    # float fixed point at exactly 1.0 is reached the estimate holds there)
    for _ in range(60):
        a.observe(1.0)
        assert 1.0 <= a.ewma <= prev
        assert a.ewma < prev or a.ewma == 1.0
        prev = a.ewma
    assert a.bound == 1


def test_adaptive_bound_clamps_to_min_max():
    a = AdaptiveStaleness(AdaptiveStalenessSpec(s_min=1, s_max=2, beta=1.0))
    assert a.bound == 1                  # ewma 0 clamps up to s_min
    a.observe(7.0)                       # beta=1: ewma jumps to the lag
    assert a.bound == 2                  # clamps down to s_max
    a.observe(0.0)
    assert a.bound == 1


def test_adaptive_constant_lag_is_bitwise_fixed_point():
    """lag == ewma gives a delta of exactly 0.0: the estimate (and hence
    the bound) never drifts under a constant lag stream -- the property
    that makes adaptive-S reproduce fixed-S bitwise."""
    a = AdaptiveStaleness(AdaptiveStalenessSpec(init=2.0, beta=0.25))
    for _ in range(100):
        a.observe(2.0)
        assert a.ewma == 2.0             # exact, not approximate
    assert a.bound == 2
    z = AdaptiveStaleness(AdaptiveStalenessSpec(init=0.0))
    for _ in range(100):
        z.observe(0.0)
        assert z.ewma == 0.0
    assert z.bound == 0


def test_adaptive_spec_validation():
    with pytest.raises(ValueError, match="beta"):
        AdaptiveStalenessSpec(beta=0.0)
    with pytest.raises(ValueError, match="s_max"):
        AdaptiveStalenessSpec(s_min=3, s_max=1)
    with pytest.raises(ValueError, match="init"):
        AdaptiveStalenessSpec(init=-0.5)
    a = AdaptiveStaleness(AdaptiveStalenessSpec())
    with pytest.raises(ValueError, match="lag"):
        a.observe(-1.0)


def test_adaptive_s_reproduces_fixed_s_bitwise(model, tiny_federation):
    """No stragglers => every commit lag is 0 => the adaptive bound sits
    at 0 and the whole trajectory equals the fixed S=0 run bitwise."""
    cfg = _cfg()
    runs = []
    for adaptive in (None, AdaptiveStalenessSpec(s_min=0, s_max=4,
                                                 beta=0.25, init=0.0)):
        eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                            mesh=make_mediator_mesh(1))
        a = AsyncRoundEngine(eng, AsyncSpec(
            staleness_bound=0, wave_size=1,
            straggler=StragglerSpec(model="none"), adaptive=adaptive))
        for _ in range(3):
            a.run_round()
        runs.append(a)
    _params_bitwise(runs[0].params, runs[1].params)
    assert runs[1].staleness_bound == 0
    assert runs[1]._adaptive.num_observed > 0
    # every commit logged the bound that governed it
    assert all(c["staleness_bound"] == 0 for c in runs[1].commit_log)


# ----------------------------------------------------------------------
# client-level straggler model + wave co-scheduling
# ----------------------------------------------------------------------
def test_client_level_model_needs_num_clients():
    spec = StragglerSpec(model="fixed", level="client")
    with pytest.raises(ValueError, match="num_clients"):
        StragglerModel(spec, num_slots=4)
    m = StragglerModel(spec, num_slots=4, num_clients=12)
    with pytest.raises(ValueError, match="durations_for_groups"):
        m.durations(np.ones(4))
    med = StragglerModel(StragglerSpec(model="none"), num_slots=4)
    with pytest.raises(ValueError, match="level='client'"):
        med.durations_for_groups([[0, 1]])


def test_slow_clients_drag_their_mediators_into_late_waves():
    spec = StragglerSpec(model="fixed", straggler_frac=0.25, slowdown=8.0,
                         seed=1, level="client")
    m = StragglerModel(spec, num_slots=4, num_clients=12)
    slow = set(np.flatnonzero(m.factors > 1.0).tolist())
    assert len(slow) == 3                # round(0.25 * 12)
    groups = [[0, 1, 2], [3, 4, 5], [6, 7, 8], [9, 10, 11]]
    durations = m.durations_for_groups(groups, epochs=2)
    waves, _ = scheduling.partition_waves(durations, 1)
    # whichever mediators hold slow clients come strictly after the
    # all-fast mediators in the wave order
    has_slow = [bool(slow & set(g)) for g in groups]
    order = [int(w[0]) for w in waves]
    fast_positions = [order.index(g) for g in range(4) if not has_slow[g]]
    slow_positions = [order.index(g) for g in range(4) if has_slow[g]]
    assert max(fast_positions) < min(slow_positions)


def test_unit_speed_clients_reproduce_mediator_ordering_bitwise():
    """All-equal-speed client factors degenerate to the historical
    mediator-level ordering: identical duration vectors (the float sum of
    k ones is exactly k), identical waves."""
    groups = [[0, 1, 2], [3, 4], [5, 6, 7], [8]]
    cl = StragglerModel(StragglerSpec(model="none", level="client"),
                        num_slots=4, num_clients=9)
    med = StragglerModel(StragglerSpec(model="none"), num_slots=4)
    d_client = cl.durations_for_groups(groups, epochs=2)
    work = np.asarray([len(g) for g in groups], np.float64) * 2
    d_med = med.durations(work)
    np.testing.assert_array_equal(d_client, d_med)
    w_client, _ = scheduling.partition_waves(d_client, 2)
    w_med, _ = scheduling.partition_waves(d_med, 2)
    assert [list(map(int, w)) for w in w_client] == \
        [list(map(int, w)) for w in w_med]


def test_client_level_async_rounds_run(model, tiny_federation):
    """End-to-end: the async engine derives durations from the schedule's
    group membership (engine.last_groups) under level='client'."""
    cfg = _cfg()
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(
        staleness_bound=1, wave_size=1,
        straggler=StragglerSpec(model="fixed", straggler_frac=0.25,
                                slowdown=4.0, seed=0, level="client")))
    for _ in range(2):
        a.run_round()
    assert a.num_commits == 2
    assert a._straggler.factors.shape[0] == tiny_federation.num_clients
    assert eng.last_groups is not None
    # durations actually reflect membership sums, not unit slot work
    d = a._straggler.durations_for_groups(eng.last_groups,
                                          cfg.mediator_epochs)
    assert d.shape[0] == len(eng.last_groups)


# ----------------------------------------------------------------------
# overlapped dispatch: bitwise pins + pipeline observability
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ["replicated", "sharded"])
def test_overlapped_s0_bitwise_matches_sync(model, tiny_federation, store):
    """Overlapped dispatch (sliced waves + pipelined commits; masked
    fallback under the row-permuting sharded store) reproduces the sync
    engine bitwise at S=0, across per-round reschedules, with zero
    retraces."""
    cfg = _cfg(store=store)
    sync = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                         mesh=make_mediator_mesh(1))
    for _ in range(3):
        sync.run_round()
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(
        staleness_bound=0, wave_size=1,
        straggler=StragglerSpec(model="lognormal", seed=3),
        dispatch="overlapped"))
    assert a._pipelined
    assert a._sliced == (store == "replicated")
    for _ in range(3):
        a.run_round()
    a.flush()
    _params_bitwise(sync.params, a.params)
    # one "initial" trace per wave width, zero retraces across
    # reschedules -- widths recur, executables are cached
    assert all(t["reason"] == "initial" for t in eng.trace_log), \
        eng.trace_log
    fns = [t["fn"] for t in eng.trace_log]
    assert len(fns) == len(set(fns))
    if store == "replicated":
        assert all(f.startswith("wave_fn[") for f in fns)
    assert a.num_dispatches > 0


def test_overlapped_s1_bitwise_matches_masked(model, tiny_federation):
    """Sliced execution is a pure dispatch change: at S=1 under a
    straggler fleet, overlapped and masked runs commit identical bits
    round for round (row_exec='map')."""
    cfg = _cfg()
    spec = dict(staleness_bound=1, wave_size=1,
                straggler=StragglerSpec(model="fixed", straggler_frac=0.34,
                                        slowdown=4.0, seed=0))
    runs = []
    for dispatch in ("masked", "overlapped"):
        eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                            mesh=make_mediator_mesh(1))
        a = AsyncRoundEngine(eng, AsyncSpec(dispatch=dispatch, **spec))
        for _ in range(3):
            a.run_round()
        a.flush()
        runs.append(a)
    _params_bitwise(runs[0].params, runs[1].params)
    assert runs[0].commit_log[-1]["staleness"] == \
        runs[1].commit_log[-1]["staleness"]


def test_blocking_baseline_reports_zero_overlap(model, tiny_federation):
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, _cfg(),
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec(
        staleness_bound=0, wave_size=1,
        straggler=StragglerSpec(model="lognormal", seed=3),
        block_each_wave=True))
    a.run_round()
    assert a.overlap_frac == 0.0
    assert a.num_dispatches >= 2
    waited = a.synchronize()
    assert waited >= 0.0 and a.num_syncs == 1


def test_async_spec_dispatch_validation():
    with pytest.raises(ValueError, match="dispatch"):
        AsyncSpec(dispatch="bogus")
    with pytest.raises(ValueError, match="blocking baseline"):
        AsyncSpec(dispatch="overlapped", block_each_wave=True)


def test_zero_round_guards(model, tiny_federation):
    """flush() before any round and fit(0) are no-ops; sim_speedup is
    exactly 1.0 with no commits (regression: was 0/eps = 0x)."""
    eng = FLRoundEngine(model, adam(1e-3), tiny_federation, _cfg(),
                        mesh=make_mediator_mesh(1))
    a = AsyncRoundEngine(eng, AsyncSpec())
    assert a.sim_speedup == 1.0
    a.flush()                            # nothing pending, nothing folded
    assert a.num_commits == 0 and a.virtual_time == 0.0
    assert a.fit(0) == []
    assert a.history == []


# ----------------------------------------------------------------------
# spilled store: depth-N prefetch + LRU cache
# ----------------------------------------------------------------------
def test_spilled_depth_and_lru_do_not_perturb_trajectories(
        model, tiny_federation):
    """Deeper pre-draw only changes WHEN selection draws are issued, not
    their order, and the LRU is a read-through cache -- trajectories stay
    bitwise across depth/lru settings, while the stats schema reports the
    knobs."""
    runs = {}
    for depth, lru in ((1, None), (3, None), (2, 1)):
        cfg = _cfg(store="spilled", store_prefetch_depth=depth,
                   store_lru_rows=lru)
        eng = FLRoundEngine(model, adam(1e-3), tiny_federation, cfg,
                            mesh=make_mediator_mesh(1))
        for _ in range(3):
            eng.run_round()
        runs[(depth, lru)] = eng
    base = runs[(1, None)]
    for key, eng in runs.items():
        _params_bitwise(base.params, eng.params)
        st = eng.store.stats()
        assert st["prefetch_depth"] == key[0]
        assert "lru_rows" in st and "lru_evictions" in st
    # the deep pipeline actually queued ahead: depth-3 run prefetched
    # every subsequent schedule and a tiny LRU was forced to evict
    deep = runs[(3, None)].store
    assert deep.prefetch_depth == 3 and deep.prefetch_hits >= 2
    assert runs[(2, 1)].store.stats()["lru_evictions"] > 0
    assert base.store.stats()["lru_rows"] == 2 * base.store._cap


def test_engine_validates_store_pipeline_knobs(model, tiny_federation):
    with pytest.raises(ValueError, match="prefetch"):
        _cfg(store_prefetch_depth=0)
    with pytest.raises(ValueError, match="lru"):
        _cfg(store_lru_rows=-1)


# ----------------------------------------------------------------------
# 4-device pin: overlapped S=0 bitwise vs sync across all three stores
# ----------------------------------------------------------------------
_OVERLAP_4DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import numpy as np
    from repro.core import LocalSpec
    from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
    from repro.core.engine import EngineConfig, FLRoundEngine
    from repro.core.staleness import StragglerSpec
    from repro.data.federated import partition, EMNIST_LIKE
    from repro.launch.mesh import make_mediator_mesh
    from repro.models.cnn import emnist_cnn
    from repro.optim import adam

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=8, image_size=16)
    fed = partition(spec, num_clients=12, total_samples=600, test_samples=160,
                    sizes="instagram", global_dist="letterfreq",
                    local="random", seed=0, name="tiny")
    model = emnist_cnn(8, image_size=16)
    aspec = AsyncSpec(staleness_bound=0, wave_size=1,
                      straggler=StragglerSpec(model="lognormal", seed=3),
                      dispatch="overlapped")
    for store in ("replicated", "sharded", "spilled"):
        cfg = EngineConfig.astraea(clients_per_round=6, gamma=3,
                                   local=LocalSpec(10, 1), seed=0,
                                   pad_mediators_to=4, donate_params=False,
                                   reschedule_every_round=True,
                                   row_exec="map", store=store)
        sync = FLRoundEngine(model, adam(1e-3), fed, cfg,
                             mesh=make_mediator_mesh(4))
        sync.run_round()
        sync.run_round()
        eng = FLRoundEngine(model, adam(1e-3), fed, cfg,
                            mesh=make_mediator_mesh(4))
        a = AsyncRoundEngine(eng, aspec)
        a.run_round()
        a.run_round()
        a.flush()
        for x, y in zip(jax.tree.leaves(sync.params),
                        jax.tree.leaves(a.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        bad = [t for t in eng.trace_log if t["reason"] != "initial"]
        assert not bad, (store, eng.trace_log)
        print(store, "ok:", sorted({t["fn"] for t in eng.trace_log}))
    print("OK")
""")


def test_overlapped_multi_device_all_stores(tmp_path):
    """Pipelined S=0 == sync, bitwise, on a real 4-device mediator mesh
    across replicated / sharded / spilled stores -- one trace per wave
    width, zero retraces. Subprocess: the device count must be forced
    before jax initializes."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _OVERLAP_4DEV_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
