"""Property tests for the distribution/KLD substrate (Astraea's metric)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import distribution as dist

counts_arrays = st.integers(2, 12).flatmap(
    lambda c: st.lists(
        st.lists(st.floats(0, 1000), min_size=c, max_size=c),
        min_size=1, max_size=8))


@given(counts_arrays)
@settings(max_examples=50, deadline=None)
def test_kld_nonnegative(rows):
    counts = jnp.asarray(np.asarray(rows) + 1e-3)
    kld = dist.kld_to_uniform(counts)
    assert np.all(np.asarray(kld) >= -1e-6)


@given(st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_kld_zero_iff_uniform(c):
    uniform_counts = jnp.full((c,), 7.0)
    assert float(dist.kld_to_uniform(uniform_counts)) == pytest.approx(0.0, abs=1e-6)
    skewed = jnp.asarray([10.0] + [0.1] * (c - 1))
    assert float(dist.kld_to_uniform(skewed)) > 0.1


def test_kld_matches_scipy():
    from scipy.stats import entropy
    p = np.array([5.0, 3.0, 2.0, 10.0])
    ours = float(dist.kld_to_uniform(jnp.asarray(p)))
    theirs = entropy(p / p.sum(), np.full(4, 0.25))
    assert ours == pytest.approx(theirs, rel=1e-5)


def test_merged_scores_match_loop():
    rng = np.random.default_rng(1)
    med = rng.uniform(0, 50, 10)
    clients = rng.uniform(0, 50, (23, 10))
    vec = np.asarray(dist.merged_kld_scores(jnp.asarray(med), jnp.asarray(clients)))
    for i in range(23):
        single = float(dist.kld_to_uniform(jnp.asarray(med + clients[i])))
        assert vec[i] == pytest.approx(single, rel=1e-5)


def test_class_histogram_mask():
    labels = jnp.asarray([0, 1, 1, 2, 2, 2])
    mask = jnp.asarray([1, 1, 1, 1, 0, 0], jnp.float32)
    h = dist.class_histogram(labels, 4, mask)
    assert np.allclose(np.asarray(h), [1, 2, 1, 0])


def test_imbalance_summary_direction():
    balanced = jnp.full((10, 8), 5.0)
    skew = jnp.asarray(np.eye(10, 8) * 40 + 0.5)
    s_bal = dist.imbalance_summary(balanced)
    s_skew = dist.imbalance_summary(skew)
    assert float(s_skew["local_kld_mean"]) > float(s_bal["local_kld_mean"])
