"""Integration test for the multi-pod dry-run entry point (deliverable e).

Runs launch/dryrun.py in a SUBPROCESS (it must set
--xla_force_host_platform_device_count=512 before jax init, which cannot
happen inside this pytest process) for one cheap (arch x shape) and checks
the JSON artifact: 256-chip lowering succeeded, roofline terms present.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch,shape", [("whisper-base", "decode_32k")])
def test_dryrun_subprocess_single_pod(tmp_path, arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=480)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]

    out = json.load(open(tmp_path / f"{arch}__{shape}__single16x16.json"))
    assert out["status"] == "ok"
    assert out["n_chips"] == 256
    rl = out["roofline"]
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert all(rl[k] >= 0 for k in ("compute_s", "memory_s", "collective_s"))
    assert out["memory"]["peak_estimate_gb"] > 0
    assert out["hlo_costs"]["while_trips"]        # layer scan detected


def test_dryrun_skip_logic_artifact(tmp_path):
    """long_500k on a full-attention arch must produce a documented skip."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma-2b",
         "--shape", "long_500k", "--out", str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0
    out = json.load(open(tmp_path / "gemma-2b__long_500k__single16x16.json"))
    assert out["status"] == "skipped"
    assert "full-attention" in out["skip_reason"]
