"""benchmarks/gate.py: the evidence-diffing perf gate.

The gate is itself gated here: it must pass on untouched evidence, fail
(exit 1) on injected time/byte regressions, refuse (exit 2) to compare
across interpret/Mosaic or backend boundaries, and fail when a bench row
silently disappears. The committed experiments/results baselines are
checked for self-consistency (gate(x, x) == pass) so a malformed baseline
can never make the CI job vacuous.
"""
import copy
import json
import os

import pytest

from benchmarks import gate

BASELINES = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "results")

EVIDENCE = {
    "fedavg_agg": {"us": 100.0, "ref_us": 50.0, "shape": "8x65536",
                   "interpret": True, "flops": 1048576.0, "bytes": 2359328.0,
                   "roofline_us": 2.88, "bound": "memory",
                   "achieved_frac": 2.9e-5},
    "nested": {"inner": {"kernel_us": 10.0, "store_bytes": 4096,
                         "traces": 1, "ok": True}},
    "wall_clock": {"wall_tta_speedup": 4.0, "overlap_frac": 0.8,
                   "wall_time_to_target_s": 9.0},
    "_meta": {"backend": "cpu", "interpret": True, "device_count": 1,
              "jax_version": "0.4.37"},
}


def _pair(tmp_path, mutate=None):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir(exist_ok=True), fresh.mkdir(exist_ok=True)
    (base / "kernels.json").write_text(json.dumps(EVIDENCE))
    ev = copy.deepcopy(EVIDENCE)
    if mutate:
        mutate(ev)
    (fresh / "kernels.json").write_text(json.dumps(ev))
    return str(fresh), str(base)


def _run(tmp_path, mutate=None, tolerance=3.0):
    fresh, base = _pair(tmp_path, mutate)
    return gate.main(["--fresh", fresh, "--baseline", base,
                      "--files", "kernels", "--tolerance", str(tolerance)])


def test_gate_passes_on_identical_evidence(tmp_path):
    assert _run(tmp_path) == 0


def test_gate_passes_within_time_tolerance(tmp_path):
    def faster_and_slightly_slower(ev):
        ev["fedavg_agg"]["us"] = 250.0          # 2.5x < 3x tolerance
        ev["nested"]["inner"]["kernel_us"] = 1.0  # faster is always fine
    assert _run(tmp_path, faster_and_slightly_slower) == 0


def test_gate_fails_on_time_regression(tmp_path):
    def slow(ev):
        ev["nested"]["inner"]["kernel_us"] = 31.0   # 3.1x > 3x
    assert _run(tmp_path, slow) == 1


def test_gate_fails_on_collapsed_ratio(tmp_path):
    """Higher-is-better ratios (*_speedup, *_frac) are gated from below:
    the measured overlap win must not collapse past baseline/tolerance."""
    def collapse(ev):
        ev["wall_clock"]["wall_tta_speedup"] = 1.0  # 4.0/3 = 1.33 floor
    assert _run(tmp_path, collapse) == 1

    def jitter(ev):
        ev["wall_clock"]["overlap_frac"] = 0.4      # above 0.8/3 floor
        ev["wall_clock"]["wall_tta_speedup"] = 9.0  # higher is never a fail
    assert _run(tmp_path, jitter) == 0


def test_gate_fails_on_byte_or_analytic_drift(tmp_path):
    for field, value in (("bytes", 2359329.0), ("flops", 1.0),
                         ("roofline_us", 5.0), ("shape", "8x128"),
                         ("bound", "compute")):
        def drift(ev, f=field, v=value):
            ev["fedavg_agg"][f] = v
        assert _run(tmp_path, drift) == 1, field


def test_gate_fails_on_nested_exact_fields(tmp_path):
    def drift(ev):
        ev["nested"]["inner"]["store_bytes"] = 8192
    assert _run(tmp_path, drift) == 1
    def traces(ev):
        ev["nested"]["inner"]["traces"] = 2
    assert _run(tmp_path, traces) == 1


def test_gate_fails_on_boolean_flip_and_missing_row(tmp_path):
    def flip(ev):
        ev["nested"]["inner"]["ok"] = False
    assert _run(tmp_path, flip) == 1
    def vanish(ev):
        del ev["fedavg_agg"]
    assert _run(tmp_path, vanish) == 1


def test_gate_ignores_derived_and_extra_fields(tmp_path):
    def noise(ev):
        ev["fedavg_agg"]["achieved_frac"] = 0.9    # derived from time
        ev["fedavg_agg"]["ref_us"] = 140.0         # within tolerance
        ev["brand_new_row"] = {"us": 1.0}          # additions are fine
    assert _run(tmp_path, noise) == 0


def test_gate_refuses_interpret_vs_mosaic(tmp_path):
    def mosaic(ev):
        ev["_meta"] = {"backend": "tpu", "interpret": False,
                       "device_count": 4, "jax_version": "0.4.37"}
        ev["fedavg_agg"]["us"] = 0.5
    assert _run(tmp_path, mosaic) == 2


def test_gate_refuses_missing_meta(tmp_path):
    def strip(ev):
        del ev["_meta"]
    assert _run(tmp_path, strip) == 2


def test_gate_refuses_missing_files(tmp_path):
    fresh, base = _pair(tmp_path)
    assert gate.main(["--fresh", fresh, "--baseline", base,
                      "--files", "kernels,absent"]) == 2


# the evidence files the perf-gate CI job actually diffs (a superset of
# gate.DEFAULT_FILES, which is only the CLI default)
CI_GATED_FILES = "kernels,agg,lora,async"


def test_committed_baselines_self_consistent():
    """gate(baseline, baseline) must pass for every committed evidence
    file the CI job diffs -- otherwise the perf-gate job is vacuous."""
    for name in CI_GATED_FILES.split(","):
        path = os.path.join(BASELINES, f"{name}.json")
        assert os.path.exists(path), f"missing committed baseline {name}"
        refusals, regressions = gate.gate_file(path, path)
        assert refusals == [] and regressions == [], name
    with open(os.path.join(BASELINES, "kernels.json")) as f:
        kernels = json.load(f)
    # the roofline evidence fields the ISSUE promises are actually there
    row = kernels["fedavg_agg"]
    for field in ("us", "flops", "bytes", "roofline_us", "achieved_frac",
                  "bound", "interpret"):
        assert field in row, field
    assert kernels["_meta"]["backend"]
    assert "interpret" in kernels["_meta"]


def test_gate_detects_perturbed_committed_baseline(tmp_path):
    """End-to-end against the REAL committed kernels baseline: a 10x
    slowdown and a one-byte analytic drift must both fail the gate."""
    with open(os.path.join(BASELINES, "kernels.json")) as f:
        ev = json.load(f)
    ev["fedavg_agg"]["us"] *= 10
    ev["kld_greedy_picks"]["bytes"] += 1
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    (fresh / "kernels.json").write_text(json.dumps(ev))
    assert gate.main(["--fresh", str(fresh), "--baseline", BASELINES,
                      "--files", "kernels"]) == 1
