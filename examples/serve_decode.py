"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving path used by the decode_32k / long_500k dry-runs:
batched prefill fills the KV/SSM cache, then serve_step decodes one token
per request per step. Works for every assigned architecture family
(default: the SSM, whose cache is O(1) in sequence length).

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-370m
  PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-1.8b
"""
import argparse
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--batch", "4",
                "--prompt-len", "64", "--tokens", str(args.tokens)]
    serve.main()


if __name__ == "__main__":
    main()
