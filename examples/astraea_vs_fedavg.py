"""The paper's headline experiment (Fig. 4/5): FedAvg vs augmentation-only
vs full Astraea on globally-imbalanced data, with the communication ledger
and (optionally) bounded-staleness async rounds under a 4x straggler.

  PYTHONPATH=src python examples/astraea_vs_fedavg.py           # EMNIST-like
  PYTHONPATH=src python examples/astraea_vs_fedavg.py --cinic   # CINIC-like
  PYTHONPATH=src python examples/astraea_vs_fedavg.py --staleness 1
"""
import argparse
import dataclasses

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.core.async_engine import AsyncSpec
from repro.core.fedavg import FedAvgTrainer
from repro.core.staleness import StragglerSpec
from repro.data.federated import partition, EMNIST_LIKE, CINIC_LIKE
from repro.models.cnn import emnist_cnn, cinic_cnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cinic", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--staleness", type=int, default=None, metavar="S",
                    help="also run Astraea with bounded-staleness async "
                         "rounds (wave per mediator, one 4x straggler)")
    ap.add_argument("--store", default="replicated",
                    choices=("replicated", "sharded", "host"),
                    help="ClientStore placement policy for every trainer")
    args = ap.parse_args()

    if args.cinic:
        spec = dataclasses.replace(CINIC_LIKE, image_size=16, noise=0.5,
                                   distort=0.35)
        model = cinic_cnn(spec.num_classes, image_size=16, width=16)
        gd = "normal"
        paper = "+0.0589"
    else:
        spec = dataclasses.replace(EMNIST_LIKE, num_classes=10, image_size=16,
                                   noise=0.45, distort=0.35)
        model = emnist_cnn(spec.num_classes, image_size=16)
        gd = "letterfreq"
        paper = "+0.0559"

    fed = partition(spec, num_clients=16, total_samples=1600, test_samples=600,
                    sizes="instagram", global_dist=gd, local="random", seed=0)
    local = LocalSpec(20, 2)

    rows = []
    fedavg = FedAvgTrainer(model, adam(1e-3), fed, clients_per_round=8,
                           local=local, store=args.store, seed=0)
    fa = fedavg.fit(args.rounds, eval_every=args.rounds)[-1]
    rows.append(("FedAvg", fa))

    aug_only = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=8,
                              gamma=1, local=local, alpha=0.67,
                              store=args.store, seed=0)
    ao = aug_only.fit(args.rounds, eval_every=args.rounds)[-1]
    rows.append(("Astraea (aug only)", ao))

    astraea = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=8,
                             gamma=4, local=local, mediator_epochs=1,
                             alpha=0.67, store=args.store, seed=0)
    aa = astraea.fit(args.rounds, eval_every=args.rounds)[-1]
    rows.append(("Astraea (aug+mediators)", aa))

    ha = None
    if args.staleness is not None:
        aspec = AsyncSpec(staleness_bound=args.staleness, wave_size=1,
                          straggler=StragglerSpec(model="fixed",
                                                  straggler_frac=0.34,
                                                  slowdown=4.0, seed=0))
        async_tr = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=8,
                                  gamma=4, local=local, mediator_epochs=1,
                                  alpha=0.67, store=args.store,
                                  async_spec=aspec, seed=0)
        ha = async_tr.fit(args.rounds, eval_every=args.rounds)[-1]
        rows.append((f"Astraea (async S={args.staleness})", ha))

    print(f"\n{'method':26s} {'top1':>7s} {'traffic MB':>11s}")
    for name, h in rows:
        print(f"{name:26s} {h['accuracy']:7.3f} {h['traffic_mb']:11.1f}")
    print(f"\nAstraea - FedAvg = {aa['accuracy']-fa['accuracy']:+.3f} "
          f"(paper: {paper})")
    print(f"WAN traffic ratio Astraea/FedAvg = "
          f"{aa['traffic_mb']/fa['traffic_mb']:.2f}x per round "
          f"(Table III's 0.18x comes from ~3x fewer rounds to target)")
    if ha is not None:
        print(f"async S={args.staleness} under a 4x straggler: simulated "
              f"round-time speedup {ha['sim_speedup']:.2f}x, "
              f"staleness<=({ha['staleness_max']}), "
              f"acc delta vs sync Astraea {ha['accuracy']-aa['accuracy']:+.3f}")


if __name__ == "__main__":
    main()
