"""Astraea on the transformer stack: federated LM training on the mesh.

The paper's mediators/rescheduling applied to an assigned architecture
(reduced variant on CPU; the same `make_fl_round` program lowers on the
production (pod, data, model) mesh -- see EXPERIMENTS.md §Dry-run). Shows:
Alg. 3 scheduling of non-IID token streams onto mediators, then one-XLA-
program synchronization rounds with weighted delta all-reduce (Eq. 6).

  PYTHONPATH=src python examples/federated_llm.py --arch hymba-1.5b
"""
import argparse

from repro.launch import fl_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()
    import sys
    sys.argv = ["fl_train", "--arch", args.arch, "--rounds", "3",
                "--clients", "8", "--gamma", "4", "--seq", "128"]
    fl_train.main()


if __name__ == "__main__":
    main()
