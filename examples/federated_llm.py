"""Astraea on the transformer stack: federated LM training on the mesh.

The paper's mediators/rescheduling applied to an assigned architecture
(reduced variant on CPU; the same `make_fl_round` program lowers on the
production (pod, data, model) mesh -- see EXPERIMENTS.md §Dry-run). Shows:
Alg. 3 scheduling of non-IID token streams onto mediators, then one-XLA-
program synchronization rounds with weighted delta all-reduce (Eq. 6) --
the round delegates its shard_map + psum Eq. 6 to the engine's shared
helpers (core/engine.py), so this IS the same round implementation the
CNN simulator runs.

  PYTHONPATH=src python examples/federated_llm.py --arch hymba-1.5b

``--model-parallel t`` builds the (data, model) mesh with a t-way model
axis so each mediator slice tensor-shards its replica (needs a device
count divisible by t; on CPU force host devices first, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=2 --model-parallel 2).

``--lora-rank r`` freezes the backbone and ships ONLY rank-r adapter
state over the WAN (models/lora.py mapping table); the run prints the
measured per-round WAN ledger and the adapter/full byte ratio from the
``CommMeter`` instead of leaving traffic unreported.
"""
import argparse

from repro.launch import fl_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lora-rank", type=int, default=None)
    ap.add_argument("--lora-alpha", type=float, default=None)
    args = ap.parse_args()
    import sys
    sys.argv = ["fl_train", "--arch", args.arch, "--rounds", "3",
                "--clients", "8", "--gamma", "4", "--seq", "128",
                "--model-parallel", str(args.model_parallel)]
    if args.lora_rank is not None:
        sys.argv += ["--lora-rank", str(args.lora_rank)]
    if args.lora_alpha is not None:
        sys.argv += ["--lora-alpha", str(args.lora_alpha)]
    fl_train.main()


if __name__ == "__main__":
    main()
