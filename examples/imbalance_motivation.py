"""Section II-B motivation: the five TABLE I federations, end to end.

Reproduces the paper's observation that *global* imbalance (LTRF) degrades
FedAvg while size/local imbalance (BAL2, INS) does not.

  PYTHONPATH=src python examples/imbalance_motivation.py
"""
import dataclasses

import jax.numpy as jnp

from repro.core import LocalSpec, distribution as dist
from repro.core.fedavg import FedAvgTrainer
from repro.data.federated import table1, EMNIST_LIKE
from repro.models.cnn import emnist_cnn
from repro.optim import adam


def main():
    spec = dataclasses.replace(EMNIST_LIKE, num_classes=10, image_size=16,
                               noise=0.45, distort=0.35)
    feds = table1(spec, num_clients=16, total_samples=1600, test_samples=600)
    model = emnist_cnn(spec.num_classes, image_size=16)

    print(f"{'dataset':8s} {'size_cv':>8s} {'local_kld':>10s} {'global_kld':>11s} "
          f"{'top1':>7s}")
    results = {}
    for name, fed in feds.items():
        stats = dist.imbalance_summary(jnp.asarray(fed.client_counts()))
        tr = FedAvgTrainer(model, adam(1e-3), fed, clients_per_round=8,
                           local=LocalSpec(20, 2), seed=0)
        hist = tr.fit(8, eval_every=8)
        acc = hist[-1]["accuracy"]
        results[name] = acc
        print(f"{name:8s} {float(stats['size_cv']):8.2f} "
              f"{float(stats['local_kld_mean']):10.3f} "
              f"{float(stats['global_kld']):11.3f} {acc:7.3f}")

    print(f"\nglobal-imbalance accuracy drop (INS - LTRF1): "
          f"{results['INS'] - results['LTRF1']:+.3f}  (paper: +0.079)")

    # Fig. 1(b)/(c): per-class recall under global imbalance -- the
    # minority classes are the ones the model stops predicting.
    from repro.core.fl import confusion_matrix
    from repro.data.federated import letter_frequency_probs
    import numpy as np
    fed = feds["LTRF1"]
    tr = FedAvgTrainer(model, adam(1e-3), fed, clients_per_round=8,
                       local=LocalSpec(20, 2), seed=0)
    tr.fit(8, eval_every=8)
    _, recall = confusion_matrix(model, tr.params, fed.test_images,
                                 fed.test_labels, fed.num_classes)
    freq_order = np.argsort(-letter_frequency_probs(fed.num_classes))
    print("\nper-class recall on LTRF1 (classes ordered frequent -> rare):")
    print("  " + " ".join(f"{recall[c]:.2f}" for c in freq_order))
    top = recall[freq_order[:3]].mean()
    bottom = recall[freq_order[-3:]].mean()
    print(f"  majority-3 recall {top:.2f} vs minority-3 recall {bottom:.2f} "
          f"(paper Fig. 1c: minority rows collapse)")


if __name__ == "__main__":
    main()
