"""Quickstart: Astraea vs FedAvg on a globally-imbalanced federation.

The 60-second tour of the public API: build a TABLE I-style federated
dataset, train the paper's CNN with FedAvg and with Astraea, print the
accuracy + mediator-KLD + traffic comparison.

  PYTHONPATH=src python examples/quickstart.py

``--model-parallel t`` puts the trainers on the 2-D ``(mediator, model)``
mesh: each mediator slice tensor-shards its model replica's residency over
``t`` devices (the device count must be divisible by ``t`` -- force host
devices with XLA_FLAGS=--xla_force_host_platform_device_count=4 to try
``--model-parallel 2`` on a CPU box). The trajectory is bitwise identical
to the 1-D mesh; only where the bytes live changes.
"""
import argparse
import dataclasses

from repro.core import LocalSpec
from repro.core.astraea import AstraeaTrainer
from repro.core.fedavg import FedAvgTrainer
from repro.data.federated import partition, EMNIST_LIKE
from repro.models.cnn import emnist_cnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-parallel", type=int, default=None,
                    help="model-axis size of the 2-D (mediator, model) "
                         "mesh; default: 1-D mediator mesh")
    args = ap.parse_args()
    mp = args.model_parallel

    spec = dataclasses.replace(EMNIST_LIKE, num_classes=10, image_size=16,
                               noise=0.45, distort=0.35)
    fed = partition(spec, num_clients=16, total_samples=1600, test_samples=600,
                    sizes="instagram", global_dist="letterfreq", local="random",
                    seed=0, name="LTRF-quickstart")
    model = emnist_cnn(spec.num_classes, image_size=16)
    local = LocalSpec(batch_size=20, epochs=2)
    rounds = 8

    print("== FedAvg (baseline) ==")
    fedavg = FedAvgTrainer(model, adam(1e-3), fed, clients_per_round=8,
                           local=local, seed=0, model_parallel=mp)
    fh = fedavg.fit(rounds, eval_every=4)
    for h in fh:
        print(f"  round {h['round']:3d}  acc={h['accuracy']:.3f}  "
              f"traffic={h['traffic_mb']:.0f} MB")

    print("== Astraea (online augmentation alpha=0.67 + mediators gamma=4) ==")
    astraea = AstraeaTrainer(model, adam(1e-3), fed, clients_per_round=8,
                             gamma=4, local=local, mediator_epochs=1,
                             alpha=0.67, seed=0, model_parallel=mp)
    ah = astraea.fit(rounds, eval_every=4)
    for h in ah:
        print(f"  round {h['round']:3d}  acc={h['accuracy']:.3f}  "
              f"traffic={h['traffic_mb']:.0f} MB  "
              f"mediator_kld={h.get('mediator_kld_mean', float('nan')):.3f}")

    print(f"\nAstraea improvement: "
          f"{ah[-1]['accuracy'] - fh[-1]['accuracy']:+.3f} top-1 "
          f"(paper: +0.0559 on imbalanced EMNIST)")
    # default aug_mode="online": the resample+warp runs inside the jitted
    # round, so the Fig. 9 storage cost is avoided entirely --
    # aug_mode="materialized" reproduces the paper's store-the-copies
    # deployment and realizes planned_extra_frac as actual bytes
    print(f"extra client storage from augmentation: "
          f"{astraea.extra_storage_frac:.0%} realized "
          f"(materializing would cost {astraea.planned_extra_frac:.0%} -- "
          f"paper Fig. 9 trade-off, avoided by the online pipeline)")

    # the WAN ledger behind Table III: CommMeter logs cumulative bytes
    # every round; the paper's 82% saving appears at scale because Astraea
    # needs far fewer rounds to the target accuracy
    fa_mb, as_mb = fh[-1]["traffic_mb"], ah[-1]["traffic_mb"]
    print(f"WAN traffic after {rounds} rounds: FedAvg {fa_mb:.1f} MB vs "
          f"Astraea {as_mb:.1f} MB ({as_mb / fa_mb:.2f}x per-round "
          f"surcharge; Table III wins on rounds-to-accuracy)")

    # the 2-D mesh residency story: sharded param bytes + the intra-pod
    # ledger (model-axis collectives never touch the WAN numbers above)
    st = astraea.engine.store.stats()
    if st.get("model_axis", 1) > 1:
        print(f"model_parallel={st['model_axis']}: "
              f"{st['per_device_param_bytes']} param bytes/device "
              f"(1/{st['model_axis']} of the replica), intra-pod traffic "
              f"{astraea.comm.intra_pod_megabytes:.1f} MB off the WAN ledger")


if __name__ == "__main__":
    main()
