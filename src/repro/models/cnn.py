"""The paper's two CNN classifiers, as pure-JAX functional models.

1. ``emnist_cnn`` -- Section II-B: three conv layers (12ch 5x5/s2, 18ch
   3x3/s2, 24ch 2x2/s1, all VALID padding -- this is the only padding choice
   that yields the paper's quoted 68,873 parameters for 47 classes), dropout
   0.5 after the first two convs, dense 150 ReLU, softmax head.
2. ``cinic_cnn`` -- the Keras-documentation CIFAR-10 CNN the paper cites:
   [conv32, conv32, maxpool, drop .25] x [conv64, conv64, maxpool, drop .25],
   dense 512 ReLU, drop .5, softmax head.

A model is a ``Model(init, apply)`` pair:
    params = model.init(key)
    logits = model.apply(params, images, train=..., rngs=key)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class Model:
    init: Callable[[Array], PyTree]
    apply: Callable[..., Array]
    num_classes: int
    input_shape: tuple[int, ...]
    # optional logical-axis annotation: a zero-arg callable returning a
    # ``models.layers.LogicalParam`` pytree mirroring ``init``'s output, so
    # the launcher rule tables (launch/sharding.py) can map wide dims onto
    # the tensor-parallel ``model`` mesh axis. ``None`` => the engine keeps
    # the parameters replicated along ``model`` (no residency win).
    param_specs: Callable[[], PyTree] | None = None


def _conv_spec(kh, kw, cin, cout):
    """LogicalParam pair for a conv layer: only the output-channel dim
    carries a rule-table axis ("mlp" -> model), so tensor sharding never
    touches a contraction dimension -- which is what keeps the model-axis
    gather/compute/reshard cycle bitwise (see core/engine.py §8)."""
    from repro.models import layers as L
    return {"w": L.LogicalParam((kh, kw, cin, cout),
                                ("conv", "conv", "conv_in", "mlp")),
            "b": L.LogicalParam((cout,), ("mlp",))}


def _dense_spec(din, dout, out_axis: str = "mlp"):
    from repro.models import layers as L
    return {"w": L.LogicalParam((din, dout), ("embed", out_axis)),
            "b": L.LogicalParam((dout,), (out_axis,))}


def _conv_init(key, kh, kw, cin, cout):
    wkey, _ = jax.random.split(key)
    fan_in = kh * kw * cin
    w = jax.random.normal(wkey, (kh, kw, cin, cout), jnp.float32) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _dense_init(key, din, dout):
    w = jax.random.normal(key, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
    return {"w": w, "b": jnp.zeros((dout,), jnp.float32)}


def conv2d(p, x, stride: int = 1, padding: str = "VALID") -> Array:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def dense(p, x) -> Array:
    return x @ p["w"] + p["b"]


def dropout(key, x, rate: float, train: bool) -> Array:
    if not train or rate == 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


def max_pool(x, window: int = 2) -> Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, window, window, 1), (1, window, window, 1), "VALID")


def count_params(params: PyTree) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# EMNIST CNN (Section II-B) -- 68,873 params at num_classes=47
# --------------------------------------------------------------------------

def emnist_cnn(num_classes: int = 47, image_size: int = 28) -> Model:
    def shapes(h):
        h1 = (h - 5) // 2 + 1          # conv1 5x5 s2 VALID
        h2 = (h1 - 3) // 2 + 1         # conv2 3x3 s2 VALID
        h3 = h2 - 2 + 1                # conv3 2x2 s1 VALID
        return h1, h2, h3

    _, _, h3 = shapes(image_size)
    flat = h3 * h3 * 24

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "conv1": _conv_init(k1, 5, 5, 1, 12),
            "conv2": _conv_init(k2, 3, 3, 12, 18),
            "conv3": _conv_init(k3, 2, 2, 18, 24),
            "dense1": _dense_init(k4, flat, 150),
            "out": _dense_init(k5, 150, num_classes),
        }

    def apply(params, x, *, train: bool = False, rngs: Array | None = None):
        if rngs is None:
            rngs = jax.random.PRNGKey(0)
        d1, d2 = jax.random.split(rngs)
        x = jax.nn.relu(conv2d(params["conv1"], x, stride=2))
        x = dropout(d1, x, 0.5, train)
        x = jax.nn.relu(conv2d(params["conv2"], x, stride=2))
        x = dropout(d2, x, 0.5, train)
        x = jax.nn.relu(conv2d(params["conv3"], x, stride=1))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(dense(params["dense1"], x))
        return dense(params["out"], x)

    def param_specs():
        return {
            "conv1": _conv_spec(5, 5, 1, 12),
            "conv2": _conv_spec(3, 3, 12, 18),
            "conv3": _conv_spec(2, 2, 18, 24),
            "dense1": _dense_spec(flat, 150),
            "out": _dense_spec(150, num_classes, out_axis="vocab"),
        }

    return Model(init, apply, num_classes, (image_size, image_size, 1),
                 param_specs)


# --------------------------------------------------------------------------
# CINIC-10 CNN (Keras CIFAR-10 example, as cited by the paper)
# --------------------------------------------------------------------------

def cinic_cnn(num_classes: int = 10, image_size: int = 32, channels: int = 3,
              width: int = 32) -> Model:
    """``width`` scales the channel counts (32 = paper-faithful; smaller for
    CPU-budget experiments)."""
    w1, w2 = width, width * 2
    # conv 3x3 SAME, pool /2, conv 3x3 SAME, pool /2
    h = image_size // 4
    flat = h * h * w2

    def init(key):
        ks = jax.random.split(key, 6)
        return {
            "conv1a": _conv_init(ks[0], 3, 3, channels, w1),
            "conv1b": _conv_init(ks[1], 3, 3, w1, w1),
            "conv2a": _conv_init(ks[2], 3, 3, w1, w2),
            "conv2b": _conv_init(ks[3], 3, 3, w2, w2),
            "dense1": _dense_init(ks[4], flat, 512 * width // 32),
            "out": _dense_init(ks[5], 512 * width // 32, num_classes),
        }

    def apply(params, x, *, train: bool = False, rngs: Array | None = None):
        if rngs is None:
            rngs = jax.random.PRNGKey(0)
        d1, d2, d3 = jax.random.split(rngs, 3)
        x = jax.nn.relu(conv2d(params["conv1a"], x, padding="SAME"))
        x = jax.nn.relu(conv2d(params["conv1b"], x, padding="SAME"))
        x = max_pool(x)
        x = dropout(d1, x, 0.25, train)
        x = jax.nn.relu(conv2d(params["conv2a"], x, padding="SAME"))
        x = jax.nn.relu(conv2d(params["conv2b"], x, padding="SAME"))
        x = max_pool(x)
        x = dropout(d2, x, 0.25, train)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(dense(params["dense1"], x))
        x = dropout(d3, x, 0.5, train)
        return dense(params["out"], x)

    def param_specs():
        d1 = 512 * width // 32
        return {
            "conv1a": _conv_spec(3, 3, channels, w1),
            "conv1b": _conv_spec(3, 3, w1, w1),
            "conv2a": _conv_spec(3, 3, w1, w2),
            "conv2b": _conv_spec(3, 3, w2, w2),
            "dense1": _dense_spec(flat, d1),
            "out": _dense_spec(d1, num_classes, out_axis="vocab"),
        }

    return Model(init, apply, num_classes, (image_size, image_size, channels),
                 param_specs)


def cross_entropy_loss(logits: Array, labels: Array, mask: Array | None = None) -> Array:
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1e-6)
    return jnp.mean(nll)


def accuracy(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
