"""Mamba-2 SSD (state-space duality) layer, chunked for the MXU.

Implements the SSD algorithm of arXiv:2405.21060: the selective SSM
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        (per head, A scalar)
    y_t = C_t h_t + D x_t
computed in chunks of length L so the dominant work is batched matmuls
(intra-chunk "attention-like" term + inter-chunk state recurrence), which
is exactly the TPU-friendly reformulation the paper is about -- a
``lax.scan`` carries the (h, p, n) state across chunks.

Single B/C group (ngroups=1, as mamba2-370m). A short depthwise causal
conv precedes the SSM (mamba's local conv), kernel size 4.

Shapes: x (b, l, h, p); dt (b, l, h); B,C (b, l, n); A (h,); D (h,).
Decode keeps state (b, h, p, n) + conv tail (b, d_conv_in, k-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def segsum(dA: Array) -> Array:
    """Lower-triangular cumulative sums: out[..., i, j] = sum dA[j+1..i].

    dA: (..., L). Returns (..., L, L) with -inf above the diagonal.
    """
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]      # sum over (j, i]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def causal_conv1d(x: Array, w: Array, b: Array | None = None,
                  tail: Array | None = None) -> tuple[Array, Array]:
    """Depthwise causal conv over seq. x: (bt, l, c); w: (k, c).

    Returns (y, new_tail) where tail carries the last k-1 inputs for decode.
    """
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail
    xp = jnp.concatenate([pad, x], axis=1)          # (bt, l+k-1, c)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    if b is not None:
        y = y + b
    return y, xp[:, -(k - 1):, :]


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array,
                chunk: int, init_state: Array | None = None
                ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    Args:
      x: (b, l, h, p) -- pre-activation SSM inputs per head.
      dt: (b, l, h) -- positive step sizes (post-softplus).
      A: (h,) -- negative decay rates.
      B, C: (b, l, n) -- shared across heads (ngroups=1).
      D: (h,) skip.
      chunk: chunk length L (l % L == 0).
      init_state: (b, h, p, n) or None.

    Returns:
      y: (b, l, h, p), final_state: (b, h, p, n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    L = chunk
    assert l % L == 0, (l, L)
    c = l // L

    f32 = jnp.float32
    xc = x.reshape(b, c, L, h, p)
    dtc = dt.reshape(b, c, L, h).astype(f32)
    Bc = B.reshape(b, c, L, n)
    Cc = C.reshape(b, c, L, n)
    dA = dtc * A.astype(f32)                               # (b,c,L,h) negative

    # --- intra-chunk (diagonal block): Y = (C B^T ∘ decay) (dt x)
    S = segsum(jnp.moveaxis(dA, -1, -2))                   # (b,c,h,L,L)
    decay = jnp.exp(S)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(f32), Bc.astype(f32))
    M = scores[:, :, None] * decay                          # (b,c,h,L,L)
    dx = (dtc[..., None] * xc.astype(f32))                  # (b,c,L,h,p)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, dx)

    # --- per-chunk outgoing state: S_c = sum_t decay_to_end_t dt_t B_t x_t
    dA_cum = jnp.cumsum(dA, axis=2)                         # (b,c,L,h)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,c,L,h)
    S_chunk = jnp.einsum("bclh,bcln,bclhp->bchpn", decay_to_end * dtc,
                         Bc.astype(f32), xc.astype(f32))

    # --- inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (b,c,h)
    h0 = jnp.zeros((b, h, p, n), f32) if init_state is None else init_state.astype(f32)

    def body(state, inp):
        s_c, g_c = inp                                      # (b,h,p,n), (b,h)
        out_prev = state
        state = g_c[..., None, None] * state + s_c
        return state, out_prev

    final, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (b,c,h,p,n)

    # --- inter-chunk contribution: C_t decay_from_start_t h_prev
    decay_in = jnp.exp(dA_cum)                              # (b,c,L,h)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", Cc.astype(f32), decay_in, h_prev)

    y = (y_diag + y_off).reshape(b, l, h, p) + (D.astype(f32)[None, None, :, None]
                                                * x.astype(f32))
    return y.astype(x.dtype), final


def ssd_decode_step(x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array,
                    state: Array) -> tuple[Array, Array]:
    """One-token recurrent update. x: (b, h, p); dt: (b, h); B,C: (b, n)."""
    f32 = jnp.float32
    g = jnp.exp(dt.astype(f32) * A.astype(f32))             # (b, h)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(f32), B.astype(f32), x.astype(f32))
    state = g[..., None, None] * state + upd
    y = jnp.einsum("bn,bhpn->bhp", C.astype(f32), state)
    y = y + D.astype(f32)[None, :, None] * x.astype(f32)
    return y.astype(x.dtype), state
