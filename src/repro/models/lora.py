"""Per-tensor LoRA adapter mapping tables over ``LogicalParam`` spec trees.

The WAN story of parameter-efficient federated fine-tuning: the backbone
``W`` is frozen on every participant, each weight tensor gets a rank-``r``
adapter, and ONLY the adapter state crosses the client<->server boundary.
The bookkeeping is a tunix-style *mapping table*: one entry per backbone
tensor path, recording how its adapter is shaped, initialized, merged and
costed (see models/README.md for the full contract).

Two entry kinds make the table a *heterogeneous* adapter tree:

* ``factorized`` -- the classic LoRA pair for a tensor with a real
  ``(din, dout)`` matmul shape and ``rank < min(din, dout)``.  ``A``
  ``(batch..., din, rank)`` is FROZEN and derived deterministically from a
  shared seed (both ends regenerate it; it is never on the wire --
  FFA-LoRA-style).  The trainable/exchanged state is ``B`` ``(batch...,
  rank, dout)``, zero-initialized so round 0 starts from the backbone.
  Merge rule: ``W_eff = W + (alpha / rank) * (A @ B).reshape(W.shape)``.
  Because every participant shares the same frozen ``A``, Eq. 6 on the
  ``B`` trees is *exactly* Eq. 6 on the induced weight deltas (linearity),
  so the engine's aggregation path needs no special casing.
* ``dense`` -- tensors with no usable factorization (1-D biases/norms
  after the batch axes) or ``rank >= min(din, dout)``, where a factor pair
  would cost MORE than the tensor itself.  The state entry IS the
  effective tensor: initialized as a copy of the backbone value, trained
  in place, merged by pass-through.  This is what makes the full-rank
  sweep *bitwise* equal to the full-delta oracle: at full rank every
  entry is dense, so the trained values, the ``final - start`` deltas,
  and the server's ``state + delta_agg`` fold are literally the oracle's
  own computation (a factorized ``W + (u1 + u2)`` accumulation could only
  ever match to fp tolerance against the oracle's ``(W + u1) + u2``).

``rank=0`` produces an EMPTY mapping: nothing is trainable, nothing is
exchanged, the backbone stays frozen -- the degenerate probe the tests
pin.

Batch axes: leading ``LogicalParam`` axes named in ``BATCH_AXES``
(stacked transformer layers / experts) batch the factorization, so a
``(L, d, h)`` stacked projection gets ``A: (L, d, r)``, ``B: (L, r, h)``
and a batched matmul merge.  ``din`` folds every remaining dim but the
last (a conv ``(kh, kw, cin, cout)`` factorizes as ``din = kh*kw*cin``).

Adapter trees are FLAT dicts keyed by the ``/``-joined tensor path --
one stable treedef for the engine's donated round state, independent of
the backbone's nesting.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import LogicalParam

PyTree = Any

# leading logical axes that batch the factorization instead of folding
# into din (stacked decoder layers, MoE experts)
BATCH_AXES = ("layers", "expert")
# fold_in salt for deriving the frozen-A stream off an engine seed
A_SALT = 0x10AA


def _is_spec(x) -> bool:
    return isinstance(x, LogicalParam)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def path_str(key_path) -> str:
    """Canonical ``/``-joined tensor path (the mapping-table key)."""
    return "/".join(_key_str(k) for k in key_path)


@dataclass(frozen=True)
class LoraEntry:
    """One mapping-table row: how tensor ``path`` is adapted.

    ``kind == "factorized"``: frozen ``A (batch_shape + (din, rank))``,
    trainable ``B (batch_shape + (rank, dout))``, merge
    ``W + (alpha/rank) * (A @ B).reshape(shape)``.
    ``kind == "dense"``: the state entry is the effective tensor itself
    (shape ``shape``), merged by pass-through.
    """
    path: str
    shape: tuple            # full backbone tensor shape
    axes: tuple             # the tensor's LogicalParam axis names
    batch_shape: tuple      # leading BATCH_AXES dims
    batch_axes: tuple       # their axis names
    din: int                # prod(non-batch dims except last); 0 for dense-1D
    dout: int               # last dim
    rank: int
    alpha: float
    kind: str               # "factorized" | "dense"

    @property
    def state_shape(self) -> tuple:
        if self.kind == "dense":
            return self.shape
        return self.batch_shape + (self.rank, self.dout)

    @property
    def a_shape(self) -> tuple:
        assert self.kind == "factorized"
        return self.batch_shape + (self.din, self.rank)

    @property
    def state_params(self) -> int:
        return int(np.prod(self.state_shape, dtype=np.int64))


def build_mapping(specs: PyTree, rank: int, alpha: float | None = None
                  ) -> dict[str, LoraEntry]:
    """Adapter mapping table from a ``LogicalParam`` spec tree.

    ``alpha=None`` defaults to ``alpha=rank`` (merge scale exactly 1, the
    convention that makes rank sweeps comparable).  ``rank=0`` returns the
    empty mapping (fully frozen backbone).
    """
    if rank < 0:
        raise ValueError(f"lora rank must be >= 0, got {rank}")
    if rank == 0:
        return {}
    leaves = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]
    mapping: dict[str, LoraEntry] = {}
    for key_path, spec in leaves:
        path = path_str(key_path)
        nb = 0
        while nb < len(spec.axes) and spec.axes[nb] in BATCH_AXES:
            nb += 1
        batch_shape = spec.shape[:nb]
        rest = spec.shape[nb:]
        dout = int(rest[-1]) if rest else 0
        din = int(np.prod(rest[:-1], dtype=np.int64)) if len(rest) > 1 else 0
        if len(rest) < 2 or rank >= min(din, dout):
            kind, r_eff = "dense", 0
        else:
            kind, r_eff = "factorized", rank
        mapping[path] = LoraEntry(
            path=path, shape=tuple(spec.shape), axes=tuple(spec.axes),
            batch_shape=tuple(batch_shape), batch_axes=tuple(spec.axes[:nb]),
            din=din, dout=dout, rank=r_eff,
            alpha=float(alpha) if alpha is not None else float(rank),
            kind=kind)
    return mapping


def full_rank(specs: PyTree) -> int:
    """Smallest rank at which every mapping entry degenerates to dense
    (== the full-delta oracle, bitwise)."""
    leaves = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]
    need = 1
    for _, spec in leaves:
        nb = 0
        while nb < len(spec.axes) and spec.axes[nb] in BATCH_AXES:
            nb += 1
        rest = spec.shape[nb:]
        if len(rest) >= 2:
            din = int(np.prod(rest[:-1], dtype=np.int64))
            need = max(need, min(din, int(rest[-1])))
    return need


def _path_key(key, path: str):
    """Per-tensor frozen-A key: deterministic in the path string alone, so
    both ends of the WAN regenerate the identical basis from the seed."""
    return jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def init_adapter_A(key, mapping: dict[str, LoraEntry]) -> dict:
    """The frozen factor bases: ``{path: A}`` for the factorized entries
    only (dense entries have no A).  Never shipped -- seed-derived."""
    out = {}
    for path, e in mapping.items():
        if e.kind != "factorized":
            continue
        a = jax.random.normal(_path_key(key, path), e.a_shape, jnp.float32)
        out[path] = a / np.sqrt(e.din)
    return out


def init_adapter_state(mapping: dict[str, LoraEntry], backbone: PyTree) -> dict:
    """Round-0 adapter state: zero ``B`` for factorized entries (merge is
    the identity), a copy of the backbone value for dense entries (the
    in-place-training start point of the full-delta oracle)."""
    by_path = {path_str(kp): leaf for kp, leaf
               in jax.tree_util.tree_flatten_with_path(backbone)[0]}
    out = {}
    for path, e in mapping.items():
        if e.kind == "dense":
            if path not in by_path:
                raise KeyError(f"mapping entry {path!r} not found in the "
                               "backbone param tree")
            out[path] = by_path[path]
        else:
            out[path] = jnp.zeros(e.state_shape, jnp.float32)
    return out


def merge_params(backbone: PyTree, a_tree: dict, state: dict,
                 mapping: dict[str, LoraEntry]) -> PyTree:
    """Effective weights: the jit-friendly merge of the mapping table.

    Dense entries pass the state tensor through bitwise; factorized ones
    add the scaled ``A @ B`` (computed in f32, cast back to the backbone
    dtype).  Tensors without a mapping entry (rank=0) stay frozen.
    """
    def merge_one(key_path, leaf):
        e = mapping.get(path_str(key_path))
        if e is None:
            return leaf
        if e.kind == "dense":
            return state[e.path].astype(leaf.dtype)
        upd = jnp.matmul(a_tree[e.path], state[e.path])   # batch..., din, dout
        upd = (e.alpha / e.rank) * upd.reshape(leaf.shape)
        return (leaf.astype(jnp.float32) + upd).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(merge_one, backbone)


def exchange_nbytes(mapping: dict[str, LoraEntry],
                    bytes_per_param: int = 4) -> int:
    """Bytes of ONE model-exchange leg under the mapping: the state tree
    only (frozen A is seed-derived on both ends, never on the wire)."""
    return sum(e.state_params for e in mapping.values()) * bytes_per_param


def num_trainable_params(mapping: dict[str, LoraEntry]) -> int:
    return sum(e.state_params for e in mapping.values())


def state_spec_tree(mapping: dict[str, LoraEntry], spec) -> dict:
    """A ``{path: spec}`` pytree mirroring the adapter state (shard_map
    in/out specs for the flat state dict)."""
    return {path: spec for path in mapping}


def a_spec_tree(mapping: dict[str, LoraEntry], spec) -> dict:
    return {path: spec for path, e in mapping.items()
            if e.kind == "factorized"}
