"""Mixture-of-Experts layer (GShard/Switch-style, TPU-idiomatic).

Top-k routing with per-group expert capacity. Dispatch/combine are one-hot
einsums -- the formulation whose sharding XLA SPMD understands natively:
experts live on the "model"/"expert" mesh axis, tokens on "data", and the
dispatch einsum lowers to the all-to-all that dominates the MoE roofline.

Tokens are processed in groups of ``group_size`` so the transient dispatch
tensor stays ~(T * g * k * cf) elements instead of (T * T * ...): with
g=512, k=2, cf=1.25 that is 84 MB bf16 per 32k tokens -- VMEM/remat
friendly. Overflowing tokens beyond an expert's capacity inside a group are
dropped (standard; the residual stream carries them).

Router math in fp32 (numerics!), expert FFN in model dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_capacity(group_size: int, top_k: int, n_experts: int,
                 capacity_factor: float = 1.25) -> int:
    return max(_round_up(int(np.ceil(group_size * top_k * capacity_factor / n_experts)), 4), 4)


def route_topk(router_logits: Array, top_k: int, capacity: int):
    """Build dispatch/combine tensors for one token group.

    Args:
      router_logits: (g, E) fp32.
    Returns:
      dispatch: (g, E, C) bool-ish (model dtype later), combine: (g, E, C)
      fp32 gate weights, aux: load-balance loss terms.
    """
    g, n_experts = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (g, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert queue: flatten slots
    # in (slot-major, token) order so slot-0 assignments win capacity first.
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.float32)   # (g, k, E)
    slot_major = jnp.swapaxes(onehot, 0, 1).reshape(top_k * g, n_experts)
    pos = jnp.cumsum(slot_major, axis=0) - slot_major                  # (k*g, E)
    pos = jnp.swapaxes(pos.reshape(top_k, g, n_experts), 0, 1)         # (g, k, E)
    pos_for_slot = jnp.sum(pos * onehot, axis=-1)                      # (g, k)
    keep = pos_for_slot < capacity

    pos_oh = jax.nn.one_hot(pos_for_slot, capacity, dtype=jnp.float32)  # (g, k, C)
    disp_k = onehot[..., :, None] * pos_oh[..., None, :]                # (g, k, E, C)
    disp_k = disp_k * keep[..., None, None]
    dispatch = disp_k.sum(axis=1)                                      # (g, E, C)
    combine = (disp_k * gate_vals[..., None, None]).sum(axis=1)        # (g, E, C)

    # Switch-style load-balance aux loss: E * <f_e * p_e>
    frac_tokens = jnp.mean(onehot[:, 0, :], axis=0)      # top-1 assignment share
    mean_probs = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(frac_tokens * mean_probs)
    return dispatch, combine, aux


def moe_glu(x: Array, router_w: Array, w_gate: Array, w_up: Array, w_down: Array,
            *, top_k: int, group_size: int = 512, capacity_factor: float = 1.25,
            activation: str = "silu") -> tuple[Array, Array]:
    """Token-choice top-k MoE with GLU experts.

    Args:
      x: (b, s, d).
      router_w: (d, E). w_gate/w_up: (E, d, f). w_down: (E, f, d).
    Returns:
      (y: (b, s, d), aux_loss: scalar fp32)
    """
    b, s, d = x.shape
    n_experts = router_w.shape[-1]
    tokens = b * s
    g = min(group_size, tokens)
    assert tokens % g == 0, f"tokens {tokens} not divisible by group {g}"
    n_groups = tokens // g
    capacity = moe_capacity(g, top_k, n_experts, capacity_factor)

    from repro.models.layers import constrain
    xg = constrain(x.reshape(n_groups, g, d), "moe_tokens", None, None)
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), router_w.astype(jnp.float32))
    dispatch, combine, aux = jax.vmap(lambda l: route_topk(l, top_k, capacity))(logits)

    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    disp = dispatch.astype(x.dtype)                                # (n, g, E, C)
    expert_in = jnp.einsum("ngec,ngd->necd", disp, xg)
    expert_in = constrain(expert_in, "moe_tokens", "expert", None, "embed")
    h_gate = act(jnp.einsum("necd,edf->necf", expert_in, w_gate))
    h_up = jnp.einsum("necd,edf->necf", expert_in, w_up)
    expert_out = jnp.einsum("necf,efd->necd", h_gate * h_up, w_down)
    expert_out = constrain(expert_out, "moe_tokens", "expert", None, "embed")
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), expert_out)
    return y.reshape(b, s, d), jnp.mean(aux)
