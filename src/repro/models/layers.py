"""Shared transformer/SSM layer library (pure JAX, shard-friendly).

Every weight is created with a *logical axis name* tuple so the launcher can
map logical axes -> mesh axes (repro.launch.sharding). All matmul dims that
matter for the MXU are kept 128-aligned by the configs.

Conventions:
  activations: (batch, seq, d_model), batch sharded on ("pod","data")
  attention:   GQA with n_kv heads; q heads grouped over kv heads
  caches:      dict of arrays with a leading layer axis (stacked for scan)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any

# --------------------------------------------------------------------------
# Logical-axis annotated parameter construction
# --------------------------------------------------------------------------

class LogicalParam:
    """A parameter spec: shape + logical axis names + init scale."""

    def __init__(self, shape, axes, scale=None, dtype=jnp.float32):
        assert len(shape) == len(axes), (shape, axes)
        self.shape = tuple(int(s) for s in shape)
        self.axes = tuple(axes)
        self.scale = scale
        self.dtype = dtype

    def init(self, key):
        if self.scale is None:  # fan-in
            fan_in = self.shape[0] if len(self.shape) >= 2 else 1
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        else:
            scale = self.scale
        if scale == 0.0:
            return jnp.zeros(self.shape, self.dtype)
        if scale == 1.0 and len(self.shape) == 1:
            return jnp.ones(self.shape, self.dtype)
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)


def build_params(key: Array, specs: PyTree) -> PyTree:
    """Initialize a pytree of LogicalParam specs into real arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, LogicalParam))
    keys = jax.random.split(key, len(leaves))
    vals = [spec.init(k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def logical_axes(specs: PyTree) -> PyTree:
    """Pytree of logical-axis tuples matching build_params output."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, LogicalParam))


def shape_dtype(specs: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), specs,
        is_leaf=lambda x: isinstance(x, LogicalParam))


# --------------------------------------------------------------------------
# Activation sharding constraints (MaxText-style logical activation axes)
# --------------------------------------------------------------------------
#
# Model code calls ``constrain(x, "batch", None, "heads", None)``; when a
# production mesh has been registered (launch.steps / launch.dryrun call
# ``set_activation_mesh``), this lowers to with_sharding_constraint with the
# matching mesh axes -- dims that don't divide are silently left unsharded,
# and on the 1-device CPU simulator it is a no-op.

_ACT_MESH = None
_MANUAL_AXES: frozenset = frozenset()
ACT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "moe_tokens": ("pod", "data"),   # H3b: += "model" for replicated-expert MoE
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "seq": (),        # sequence stays unsharded for compute (baseline)
    "seq_res": ("model",),  # saved residual stream: sequence-parallel (Megatron SP)
    "embed": (),
}


def set_activation_mesh(mesh) -> None:
    global _ACT_MESH
    _ACT_MESH = mesh


def set_manual_axes(axes) -> None:
    """Axes handled manually by an enclosing shard_map (e.g. the mediator
    axes in make_fl_round) -- constrain() must not mention them."""
    global _MANUAL_AXES
    _MANUAL_AXES = frozenset(axes)


# Per-layer parameter shardings for cotangent pinning (§Perf H2). Without
# this, the weight gradients produced inside the backward layer-scan are
# materialized REPLICATED in f32 and all-reduced once per (layer x
# microbatch) -- the dominant collective of the training baseline. A
# custom_vjp identity applied to the sliced layer params constrains each
# layer's weight cotangent to the parameter sharding, so XLA emits a
# reduce-scatter into the sharded gradient stack instead.

_PARAM_COT_SPECS = None


def set_param_cot_specs(tree) -> None:
    global _PARAM_COT_SPECS
    _PARAM_COT_SPECS = tree


def get_param_cot_specs():
    return _PARAM_COT_SPECS


def pin_cotangent(x, sharding):
    """Identity whose backward constrains the cotangent's sharding."""

    @jax.custom_vjp
    def f(y):
        return y

    def fwd(y):
        return y, None

    def bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, sharding),)

    f.defvjp(fwd, bwd)
    return f(x)


def constrain(x: "Array", *logical: str | None) -> "Array":
    """Dim names: a logical activation axis from ACT_RULES, or
    ``None`` -> leave unconstrained (UNCONSTRAINED, compiler's choice), or
    ``"full"`` -> force replicated (used where a gather is intended)."""
    mesh = _ACT_MESH
    if mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    from jax.sharding import NamedSharding, PartitionSpec as P
    U = P.UNCONSTRAINED
    used: set[str] = set()
    parts = []
    for dim, name in zip(x.shape, logical):
        if name is None:
            parts.append(U)
            continue
        if name == "full":
            parts.append(None)
            continue
        axes = [a for a in ACT_RULES.get(name, ())
                if a in mesh.axis_names and a not in used
                and a not in _MANUAL_AXES]
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0 and dim >= size:
            parts.append(tuple(axes) if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            parts.append(U)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                         # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (full / causal / sliding-window / decode-with-cache)
# --------------------------------------------------------------------------

def _repeat_kv(k: Array, n_rep: int) -> Array:
    """(b, s, kv, hd) -> (b, s, kv * n_rep, hd)"""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(b, s, kv * n_rep, hd)


def attention_scores(q: Array, k: Array, v: Array, *, causal: bool,
                     window: int | None = None,
                     q_offset: int | Array = 0) -> Array:
    """Reference (non-flash) attention.

    q: (b, sq, h, hd); k, v: (b, skv, h, hd). ``q_offset`` is the absolute
    position of q[0] relative to k[0] (for decode: cache_len - 1).
    Returns (b, sq, h, hd). fp32 softmax.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# Blockwise (flash-style) attention in pure XLA: the (S, S) score matrix is
# never materialized -- a lax.scan streams KV blocks with an online softmax
# (running max m, denominator l, f32 accumulator), each block body
# checkpointed so the backward recomputes block scores instead of storing
# them. This is the §Perf H4 optimization; on real TPUs the same scheme is
# the Pallas kernel (repro.kernels.flash_attention) -- this is its XLA
# lowering for dry-runs and CPU tests.

BLOCKWISE_ATTENTION = True
BLOCKWISE_MIN_SEQ = 2048
BLOCKWISE_BLOCK_K = 512


def blockwise_attention(q: Array, k: Array, v: Array, *, causal: bool,
                        window: int | None = None, q_offset: int | Array = 0,
                        block_k: int = BLOCKWISE_BLOCK_K) -> Array:
    """q: (b, sq, h, hd); k, v: (b, skv, h, hd) (kv already repeated)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    block_k = min(block_k, skv)
    assert skv % block_k == 0, (skv, block_k)
    nk = skv // block_k
    scale = 1.0 / np.sqrt(hd)
    qf = q.astype(jnp.bfloat16) if q.dtype == jnp.bfloat16 else q
    qpos = jnp.arange(sq)[:, None] + q_offset                 # (sq, 1)

    kb = jnp.moveaxis(k.reshape(b, nk, block_k, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_k, h, hd), 1, 0)

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, kidx = inputs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk).astype(jnp.float32) * scale
        kpos = kidx * block_k + jnp.arange(block_k)[None, :]  # (1, block_k)
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[..., None] * acc + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, sq), -1e30, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init,
                                  (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)            # (b, sq, h, hd)


def local_window_attention(q: Array, k: Array, v: Array, window: int) -> Array:
    """EXACT sliding-window attention as 2-chunk local attention.

    Chunk the sequence at the window size W: a query in chunk i only
    attends to keys in chunks i-1 and i, so scores are (sq, 2W) instead of
    (sq, skv) -- compute AND memory drop by skv/(2W) (16x for hymba's
    W=1024 at 32k). Causal + window masking applied inside the chunk pair.
    q, k, v: (b, s, h, d) with kv already repeated; s % W == 0.
    """
    b, sq, h, hd = q.shape
    W = window
    nc = sq // W
    scale = 1.0 / np.sqrt(hd)
    qc = q.reshape(b, nc, W, h, hd)
    kc = k.reshape(b, nc, W, h, hd)
    vc = v.reshape(b, nc, W, h, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kc], axis=2)               # (b, nc, 2W, h, d)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    s = jnp.einsum("bcqhd,bckhd->bchqk", qc, k2).astype(jnp.float32) * scale
    qpos = jnp.arange(W)[:, None] + W                        # within the 2W frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - W)                # causal + window
    first = jnp.arange(2 * W)[None, :] >= W                  # chunk 0: no prev
    mask_first = mask & first
    cidx = jnp.arange(nc)[:, None, None]
    m = jnp.where(cidx == 0, mask_first[None], mask[None])   # (nc, W, 2W)
    s = jnp.where(m[None, :, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bchqk,bckhd->bcqhd", p, v2)
    return out.reshape(b, sq, h, hd)


def gqa_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int | None = None, q_offset=0,
                  use_flash: bool = False, allow_blockwise: bool = True) -> Array:
    """GQA: q has H heads, k/v have KV heads; repeats kv to match."""
    n_rep = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if use_flash:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset)
    skv = k.shape[1]
    if not allow_blockwise and not (causal and window is not None
                                    and skv >= 2 * window and skv % window == 0):
        return attention_scores(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
    if BLOCKWISE_ATTENTION and causal and window is not None \
            and skv >= 2 * window and skv % window == 0 \
            and q.shape[1] == skv and not isinstance(q_offset, jax.Array) \
            and q_offset == 0:
        return local_window_attention(q, k, v, window)
    if BLOCKWISE_ATTENTION and skv >= BLOCKWISE_MIN_SEQ and skv % BLOCKWISE_BLOCK_K == 0:
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return attention_scores(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, cache_len,
                     *, window: int | None = None) -> Array:
    """One-token decode: q (b, 1, h, hd) against a (b, S, kv, hd) cache.

    ``cache_len`` masks positions >= cache_len (ring-buffer windows pass a
    full cache and mask nothing but the unwritten tail).
    """
    n_rep = q.shape[2] // k_cache.shape[2]
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    cache_len = jnp.asarray(cache_len).reshape(-1)            # (b,) or (1,)
    kpos = jnp.arange(k.shape[1])[None, :]                    # (1, S)
    valid = kpos < cache_len[:, None]                         # (b, S)
    if window is not None:
        valid &= kpos >= cache_len[:, None] - window
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# --------------------------------------------------------------------------
# Gated MLPs
# --------------------------------------------------------------------------

def glu_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array,
            activation: str = "silu") -> Array:
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    gate = act(jnp.einsum("bsd,df->bsf", x, w_gate))
    up = jnp.einsum("bsd,df->bsf", x, w_up)
    hidden = constrain(gate * up, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", hidden, w_down)


def mlp(x: Array, w_in: Array, b_in: Array, w_out: Array, b_out: Array) -> Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_in) + b_in)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out
