"""The assigned-architecture model zoo: one config-driven transformer stack.

Covers six families behind one ``ArchConfig``:

  dense   -- GQA + RoPE + (Swi|Ge)GLU (+ QKV bias, qk-norm, sliding window)
  moe     -- dense attention + token-choice top-k MoE FFN (GShard einsums)
  ssm     -- attention-free Mamba-2 SSD blocks
  hybrid  -- parallel attention + SSD heads per layer (Hymba-style)
  vlm     -- LM backbone consuming stub patch embeddings (InternVL2-style)
  audio   -- encoder-decoder with stub conv-frontend features (Whisper-style)

Layers are *stacked* (leading ``layers`` axis) and executed under
``jax.lax.scan`` so compile time and HLO size are O(1) in depth -- essential
for 64-80 layer dry-runs. Every parameter carries logical axis names that
``repro.launch.sharding`` maps onto the ("pod", "data", "model") mesh.

Three entry points (see repro.launch.steps for the jit'd step functions):
  ``forward_train``    full-sequence causal LM loss
  ``forward_prefill``  full sequence -> last-position logits + decode cache
  ``forward_decode``   one token + cache -> logits + updated cache
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import LogicalParam, constrain

Array = jax.Array
PyTree = Any


# ==========================================================================
# Config
# ==========================================================================

@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|vlm|audio|hybrid
    n_layers: int
    d_model: int
    n_heads: int                         # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    # attention features
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 1e4
    # mlp
    activation: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_group: int = 512
    capacity_factor: float = 1.25
    # tiny-expert MoE (d_ff << 128*TP): replicate expert weights over
    # "model" and shard token groups over (data x model) instead -- no
    # all-to-all, full-width matmuls (§Perf H3b; 6x step-time on granite)
    moe_token_parallel: bool = False
    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_kernel: int = 4
    # enc-dec (audio)
    encoder_layers: int = 0
    source_positions: int = 1536         # stub frame embeddings (whisper: 1500->pad 1536)
    # vlm
    vision_tokens: int = 0               # stub patch embeddings prepended
    # misc
    norm: str = "rms"                    # rms | ln  (whisper uses ln)
    pos: str = "rope"                    # rope | learned
    tie_embeddings: bool = False
    embed_scale: bool = False            # gemma: embeddings * sqrt(d)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    # blockwise attention in TRAIN: a measured per-arch dispatch (§Perf H9)
    # -- streaming q re-reads cost ~20% of the step bound, worth it only
    # when dense (S,S) scores pressure HBM (off for gemma/internvl2/whisper
    # whose 4k-train peaks were fine without it).
    blockwise_train: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)-ish memory per new token at 500k?"""
        return self.arch_type == "ssm" or self.sliding_window is not None

    def np_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


# ==========================================================================
# Parameter specs (LogicalParam pytrees; leading "layers" axis is stacked)
# ==========================================================================

def _attn_specs(cfg: ArchConfig, n_layers: int, dt) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    lp = lambda shape, axes, **kw: LogicalParam((n_layers,) + shape, ("layers",) + axes,
                                                dtype=dt, **kw)
    s = {
        "wq": lp((d, H * hd), ("embed", "heads")),
        "wk": lp((d, KV * hd), ("embed", "kv_heads")),
        "wv": lp((d, KV * hd), ("embed", "kv_heads")),
        "wo": lp((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = lp((H * hd,), ("heads",), scale=0.0)
        s["bk"] = lp((KV * hd,), ("kv_heads",), scale=0.0)
        s["bv"] = lp((KV * hd,), ("kv_heads",), scale=0.0)
    if cfg.qk_norm:
        s["q_norm"] = lp((hd,), ("head_dim",), scale=0.0)
        s["k_norm"] = lp((hd,), ("head_dim",), scale=0.0)
    return s


def _mlp_specs(cfg: ArchConfig, n_layers: int, dt, with_bias: bool = False) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lp = lambda shape, axes, **kw: LogicalParam((n_layers,) + shape, ("layers",) + axes,
                                                dtype=dt, **kw)
    if with_bias:  # whisper-style plain GELU MLP
        return {"w_in": lp((d, f), ("embed", "mlp")),
                "b_in": lp((f,), ("mlp",), scale=0.0),
                "w_out": lp((f, d), ("mlp", "embed")),
                "b_out": lp((d,), ("embed",), scale=0.0)}
    return {"w_gate": lp((d, f), ("embed", "mlp")),
            "w_up": lp((d, f), ("embed", "mlp")),
            "w_down": lp((f, d), ("mlp", "embed"))}


def _moe_specs(cfg: ArchConfig, n_layers: int, dt) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lp = lambda shape, axes, **kw: LogicalParam((n_layers,) + shape, ("layers",) + axes,
                                                **{"dtype": dt, **kw})
    return {"router": lp((d, E), ("embed", "expert"), dtype=jnp.float32),
            "w_gate": lp((E, d, f), ("expert", "embed", "mlp")),
            "w_up": lp((E, d, f), ("expert", "embed", "mlp")),
            "w_down": lp((E, f, d), ("expert", "mlp", "embed"))}


def _ssm_specs(cfg: ArchConfig, n_layers: int, dt) -> dict:
    d, di, n, h = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.conv_kernel
    conv_dim = di + 2 * n
    lp = lambda shape, axes, **kw: LogicalParam((n_layers,) + shape, ("layers",) + axes,
                                                dtype=dt, **kw)
    return {
        "in_proj": lp((d, 2 * di + 2 * n + h), ("embed", "ssm_proj")),
        "conv_w": lp((k, conv_dim), ("conv", "ssm_conv"), scale=0.5),
        "conv_b": lp((conv_dim,), ("ssm_conv",), scale=0.0),
        "A_log": lp((h,), ("ssm_heads",), scale=1.0),
        "D": lp((h,), ("ssm_heads",), scale=1.0),
        "dt_bias": lp((h,), ("ssm_heads",), scale=0.0),
        "norm": lp((di,), ("ssm_inner",), scale=0.0),
        "out_proj": lp((di, d), ("ssm_inner", "embed")),
    }


def _norm_specs(cfg: ArchConfig, n_layers: int, names: list[str]) -> dict:
    d = cfg.d_model
    out = {}
    for nm in names:
        out[nm] = LogicalParam((n_layers, d), ("layers", "embed"), scale=0.0,
                               dtype=jnp.float32)
        if cfg.norm == "ln":
            out[nm + "_b"] = LogicalParam((n_layers, d), ("layers", "embed"),
                                          scale=0.0, dtype=jnp.float32)
    return out


def _decoder_layer_specs(cfg: ArchConfig, n_layers: int, dt,
                         cross_attention: bool = False) -> dict:
    s: dict = {}
    if cfg.arch_type == "ssm":
        s.update(_norm_specs(cfg, n_layers, ["norm1"]))
        s["ssm"] = _ssm_specs(cfg, n_layers, dt)
        return s
    s.update(_norm_specs(cfg, n_layers, ["norm1", "norm2"]))
    s["attn"] = _attn_specs(cfg, n_layers, dt)
    if cfg.arch_type == "hybrid":
        s["ssm"] = _ssm_specs(cfg, n_layers, dt)
        s["mix_attn"] = LogicalParam((n_layers, cfg.d_model), ("layers", "embed"),
                                     scale=0.0, dtype=jnp.float32)
        s["mix_ssm"] = LogicalParam((n_layers, cfg.d_model), ("layers", "embed"),
                                    scale=0.0, dtype=jnp.float32)
    if cross_attention:
        s.update(_norm_specs(cfg, n_layers, ["norm_x"]))
        s["xattn"] = _attn_specs(cfg, n_layers, dt)
    if cfg.is_moe:
        s["moe"] = _moe_specs(cfg, n_layers, dt)
    else:
        s["mlp"] = _mlp_specs(cfg, n_layers, dt, with_bias=(cfg.norm == "ln"))
    return s


def param_specs(cfg: ArchConfig, max_seq: int = 4096) -> PyTree:
    """Full-model LogicalParam pytree. ``max_seq`` sizes learned positions."""
    dt = cfg.np_dtype()
    d = cfg.d_model
    specs: dict = {
        "embed": LogicalParam((cfg.vocab, d), ("vocab", "embed"), dtype=dt,
                              scale=1.0 / np.sqrt(d)),
        "final_norm": LogicalParam((d,), ("embed",), scale=0.0, dtype=jnp.float32),
    }
    if cfg.norm == "ln":
        specs["final_norm_b"] = LogicalParam((d,), ("embed",), scale=0.0,
                                             dtype=jnp.float32)
    if not cfg.tie_embeddings:
        specs["lm_head"] = LogicalParam((d, cfg.vocab), ("embed", "vocab"), dtype=dt)
    if cfg.pos == "learned":
        specs["pos_embed"] = LogicalParam((max_seq, d), ("pos", "embed"), dtype=dt,
                                          scale=0.02)
    if cfg.arch_type == "audio":
        specs["enc_pos"] = LogicalParam((cfg.source_positions, d), ("pos", "embed"),
                                        dtype=dt, scale=0.02)
        enc_cfg = dataclasses.replace(cfg, arch_type="dense", n_experts=0)
        specs["encoder"] = _decoder_layer_specs(enc_cfg, cfg.encoder_layers, dt)
        specs["enc_final_norm"] = LogicalParam((d,), ("embed",), scale=0.0,
                                               dtype=jnp.float32)
        specs["enc_final_norm_b"] = LogicalParam((d,), ("embed",), scale=0.0,
                                                 dtype=jnp.float32)
        specs["layers"] = _decoder_layer_specs(cfg, cfg.n_layers, dt,
                                               cross_attention=True)
    else:
        specs["layers"] = _decoder_layer_specs(cfg, cfg.n_layers, dt)
    return specs


def init_params(key: Array, cfg: ArchConfig, max_seq: int = 4096) -> PyTree:
    return L.build_params(key, param_specs(cfg, max_seq))


def param_count(cfg: ArchConfig, max_seq: int = 4096) -> int:
    leaves = jax.tree.leaves(param_specs(cfg, max_seq),
                             is_leaf=lambda x: isinstance(x, LogicalParam))
    return sum(int(np.prod(p.shape)) for p in leaves)


def adapter_mapping(cfg: ArchConfig, rank: int, alpha: float | None = None,
                    max_seq: int = 4096) -> dict:
    """Per-tensor LoRA adapter mapping table over this architecture's
    param specs (the ``models/lora.py`` contract): wide matmul tensors get
    rank-``rank`` factor pairs, 1-D norms/biases (and tensors the rank
    would not compress) fall back to dense entries.  The table is what the
    federated round ships over the WAN instead of full deltas."""
    from repro.models import lora
    return lora.build_mapping(param_specs(cfg, max_seq), rank, alpha)


def active_param_count(cfg: ArchConfig, max_seq: int = 4096) -> int:
    """Params touched per token (MoE: top_k of n_experts expert params)."""
    total = param_count(cfg, max_seq)
    if not cfg.is_moe:
        return total
    expert_leaf = cfg.n_layers * (2 * cfg.d_model * cfg.d_ff + cfg.d_ff * cfg.d_model)
    all_experts = expert_leaf * cfg.n_experts
    active = expert_leaf * cfg.top_k
    return total - all_experts + active


# ==========================================================================
# Norm helper
# ==========================================================================

def _norm(cfg: ArchConfig, x: Array, p: dict, name: str) -> Array:
    if cfg.norm == "ln":
        return L.layer_norm(x, p[name], p[name + "_b"], cfg.norm_eps)
    return L.rms_norm(x, p[name], cfg.norm_eps)


# ==========================================================================
# Attention block (train/prefill/decode)
# ==========================================================================

def _project_qkv(cfg: ArchConfig, p: dict, x: Array):
    b, s, _ = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, H, hd)
    k = k.reshape(b, s, KV, hd)
    v = v.reshape(b, s, KV, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = constrain(q, "batch", "full", "heads", None)
    k = constrain(k, "batch", "full", "kv_heads", None)
    v = constrain(v, "batch", "full", "kv_heads", None)
    return q, k, v


def attn_block(cfg: ArchConfig, p: dict, x: Array, positions: Array,
               *, causal: bool = True, cache: dict | None = None,
               mode: str = "train"):
    """Returns (out, new_cache). Cache layout per layer:
       full attn: {"k","v": (b, S_cache, KV, hd), "len": ()} -- ring buffer
       when cfg.sliding_window is set (S_cache == window)."""
    b, s, _ = x.shape
    if positions.ndim == 1:
        positions = positions[:, None]                     # (b,) -> (b, 1)
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.pos == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "train":
        out = L.gqa_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                              allow_blockwise=cfg.blockwise_train)
    elif mode == "prefill":
        out = L.gqa_attention(q, k, v, causal=causal, window=cfg.sliding_window)
        W = cfg.sliding_window
        if W is not None and s >= W:
            # keep last W positions, aligned to the ring buffer layout
            shift = (s % W)
            k_keep = jnp.roll(k[:, -W:], shift, axis=1)
            v_keep = jnp.roll(v[:, -W:], shift, axis=1)
            new_cache = {"k": k_keep, "v": v_keep}
        else:
            new_cache = {"k": k, "v": v}
    elif mode == "decode":
        # positions: (b,) absolute position of the new token
        pos = positions[:, 0] if positions.ndim > 1 else positions
        W = cfg.sliding_window
        if W is not None:
            slot = pos % W
        else:
            slot = pos
        k_cache = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
            c, upd, (i, 0, 0)))(cache["k"], k[:, 0:1].astype(cache["k"].dtype),
                                slot.astype(jnp.int32))
        v_cache = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
            c, upd, (i, 0, 0)))(cache["v"], v[:, 0:1].astype(cache["v"].dtype),
                                slot.astype(jnp.int32))
        cache_len = jnp.minimum(pos + 1, k_cache.shape[1])[:, None]
        if W is not None:
            out = L.decode_attention(q, k_cache, v_cache,
                                     jnp.minimum(pos + 1, W)[:, None])
        else:
            out = L.decode_attention(q, k_cache, v_cache, cache_len)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        raise ValueError(mode)

    out = out.reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_cache


def cross_attn_block(cfg: ArchConfig, p: dict, x: Array, enc_out: Array):
    """Encoder-decoder cross attention (no cache: kv recomputed, tiny)."""
    b, s, _ = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, H, hd)
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, enc_out.shape[1], KV, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, enc_out.shape[1], KV, hd)
    out = L.gqa_attention(q, k, v, causal=False)
    return jnp.einsum("bsh,hd->bsd", out.reshape(b, s, -1), p["wo"])


# ==========================================================================
# SSD block
# ==========================================================================

def ssm_block(cfg: ArchConfig, p: dict, x: Array, *, cache: dict | None = None,
              mode: str = "train"):
    """Mamba-2 block. Cache: {"state": (b,h,pd,n), "conv": (b,k-1,conv_dim)}."""
    b, s, _ = x.shape
    di, n, h, pd = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, Bc, Cc, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    tail = cache["conv"] if cache is not None else None
    conv_out, new_tail = ssm_lib.causal_conv1d(conv_in, p["conv_w"], p["conv_b"], tail)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = constrain(xs.reshape(b, s, h, pd), "batch", "seq", "ssm_heads", None)

    if mode == "decode":
        y, state = ssm_lib.ssd_decode_step(xh[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0],
                                           p["D"], cache["state"])
        y = y[:, None]
        new_cache = {"state": state, "conv": new_tail}
    else:
        init = cache["state"] if cache is not None else None
        y, state = ssm_lib.ssd_chunked(xh, dt, A, Bc, Cc, p["D"], cfg.ssm_chunk, init)
        new_cache = {"state": state, "conv": new_tail} if mode == "prefill" else None

    y = y.reshape(b, s, di)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache


# ==========================================================================
# One decoder layer (covers all families)
# ==========================================================================

def decoder_layer(cfg: ArchConfig, p: dict, x: Array, positions: Array,
                  *, mode: str, cache: dict | None, enc_out: Array | None = None,
                  causal: bool = True):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    def _sp(out):
        # Megatron-SP: keep block outputs sequence-sharded entering the
        # residual add, so tensor-parallel partial sums lower to
        # reduce-scatter instead of all-reduce (train only; §Perf H7).
        if mode == "train":
            return constrain(out, "batch", "seq_res", "embed")
        return out

    h = _norm(cfg, x, p, "norm1")
    if cfg.arch_type == "ssm":
        out, c = ssm_block(cfg, p["ssm"], h, cache=cache, mode=mode)
        if c:
            new_cache.update(c)
        return x + _sp(out), (new_cache or None), aux

    if cfg.arch_type == "hybrid":
        a_out, a_c = attn_block(cfg, p["attn"], h, positions, causal=causal,
                                cache=(cache or {}).get("attn"), mode=mode)
        s_out, s_c = ssm_block(cfg, p["ssm"], h,
                               cache=(cache or {}).get("ssm"), mode=mode)
        ga = 0.5 * (1.0 + p["mix_attn"].astype(jnp.float32))
        gs = 0.5 * (1.0 + p["mix_ssm"].astype(jnp.float32))
        out = (ga * a_out.astype(jnp.float32) + gs * s_out.astype(jnp.float32)
               ).astype(x.dtype)
        if a_c:
            new_cache["attn"] = a_c
        if s_c:
            new_cache["ssm"] = s_c
    else:
        out, a_c = attn_block(cfg, p["attn"], h, positions, causal=causal,
                              cache=(cache or {}).get("attn"), mode=mode)
        if a_c:
            new_cache["attn"] = a_c
    x = x + _sp(out)

    if enc_out is not None:
        h = _norm(cfg, x, p, "norm_x")
        x = x + _sp(cross_attn_block(cfg, p["xattn"], h, enc_out))

    h = _norm(cfg, x, p, "norm2")
    if cfg.is_moe:
        m = p["moe"]
        out, aux = moe_lib.moe_glu(h, m["router"], m["w_gate"], m["w_up"], m["w_down"],
                                   top_k=cfg.top_k, group_size=cfg.moe_group,
                                   capacity_factor=cfg.capacity_factor,
                                   activation=cfg.activation)
    elif cfg.norm == "ln":
        m = p["mlp"]
        out = L.mlp(h, m["w_in"], m["b_in"], m["w_out"], m["b_out"])
    else:
        m = p["mlp"]
        out = L.glu_mlp(h, m["w_gate"], m["w_up"], m["w_down"], cfg.activation)
    return x + _sp(out), (new_cache or None), aux


# ==========================================================================
# Layer-stack scan
# ==========================================================================

def _scan_layers(cfg: ArchConfig, stacked: PyTree, x: Array, positions: Array,
                 *, mode: str, cache: PyTree | None, enc_out: Array | None = None,
                 causal: bool = True):
    """Scan the stacked decoder layers; cache (if any) has leading L axis."""

    cot_specs = L.get_param_cot_specs()

    def body(carry, xs):
        h, aux = carry
        lp, lc = xs
        if mode == "train" and cot_specs is not None:
            try:
                spec_tree = jax.tree.map(lambda _, s: s, lp, cot_specs)
                lp = jax.tree.map(L.pin_cotangent, lp, spec_tree)
            except ValueError:
                pass  # structure mismatch (e.g. encoder stack): skip pinning
        h = constrain(h, "batch", None, None)
        h, new_c, a = decoder_layer(cfg, lp, h, positions, mode=mode, cache=lc,
                                    enc_out=enc_out, causal=causal)
        if mode == "train":
            # the carry is the only tensor remat saves per layer: store it
            # sequence-parallel (Megatron SP) so 64-80 layer stacks fit HBM.
            h = constrain(h, "batch", "seq_res", "embed")
        return (h, aux + a), new_c

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    (x, aux), new_cache = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                       (stacked, cache))
    return x, aux, new_cache


# ==========================================================================
# Forward passes
# ==========================================================================

def _embed_inputs(cfg: ArchConfig, params: PyTree, batch: dict) -> tuple[Array, Array]:
    """Token (+modality) embedding. Returns (h, positions)."""
    tokens = batch["tokens"]
    h = params["embed"][tokens].astype(cfg.np_dtype())
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    if cfg.arch_type == "vlm":
        vis = batch["vision_embeds"].astype(h.dtype)      # (b, V, d) stub frontend
        h = jnp.concatenate([vis, h], axis=1)
    b, s = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos == "learned":
        h = h + params["pos_embed"][:s][None].astype(h.dtype)
    return constrain(h, "batch", "seq", "embed"), positions


def _run_encoder(cfg: ArchConfig, params: PyTree, enc_feats: Array) -> Array:
    """Audio encoder over stub conv-frontend features (b, S_src, d)."""
    h = enc_feats.astype(cfg.np_dtype())
    s = h.shape[1]
    h = h + params["enc_pos"][:s][None].astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], h.shape[:2])
    enc_cfg = dataclasses.replace(cfg, arch_type="dense", n_experts=0)
    h, _, _ = _scan_layers(enc_cfg, params["encoder"], h, positions,
                           mode="train", cache=None, causal=False)
    return L.layer_norm(h, params["enc_final_norm"], params["enc_final_norm_b"],
                        cfg.norm_eps)


def _lm_head(cfg: ArchConfig, params: PyTree, h: Array) -> Array:
    h = _norm(cfg, h, params, "final_norm")
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def forward_train(params: PyTree, cfg: ArchConfig, batch: dict) -> tuple[Array, dict]:
    """Causal-LM loss over the batch. Returns (loss, metrics)."""
    enc_out = None
    if cfg.arch_type == "audio":
        enc_out = _run_encoder(cfg, params, batch["enc_feats"])
    h, positions = _embed_inputs(cfg, params, batch)
    h, aux, _ = _scan_layers(cfg, params["layers"], h, positions, mode="train",
                             cache=None, enc_out=enc_out)
    if cfg.arch_type == "vlm":                      # loss only on the text span
        h = h[:, cfg.vision_tokens:]
    logits = _lm_head(cfg, params, h)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if cfg.is_moe:
        loss = loss + 0.01 * aux
    return loss, {"loss": loss, "aux": aux}


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               dtype=None) -> PyTree:
    """Decode cache with leading layer axis (matches the scan)."""
    dt = dtype or cfg.np_dtype()
    Lr, b = cfg.n_layers, batch_size
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    S = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len

    def attn_cache():
        return {"k": jnp.zeros((Lr, b, S, KV, hd), dt),
                "v": jnp.zeros((Lr, b, S, KV, hd), dt)}

    def ssm_cache():
        return {"state": jnp.zeros((Lr, b, cfg.ssm_heads, cfg.ssm_head_dim,
                                    cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((Lr, b, cfg.conv_kernel - 1,
                                   cfg.ssm_inner + 2 * cfg.ssm_state), dt)}

    if cfg.arch_type == "ssm":
        return ssm_cache()
    if cfg.arch_type == "hybrid":
        return {"attn": attn_cache(), "ssm": ssm_cache()}
    return {"attn": attn_cache()}


def forward_prefill(params: PyTree, cfg: ArchConfig, batch: dict,
                    pad_to: int | None = None) -> tuple[Array, PyTree]:
    """Full-sequence prefill: last-position logits + populated cache.

    ``pad_to`` grows full-attention KV caches to the decode budget so
    subsequent ``forward_decode`` steps can write past the prompt length
    (SWA ring buffers and SSM states are already fixed-size).
    """
    enc_out = None
    if cfg.arch_type == "audio":
        enc_out = _run_encoder(cfg, params, batch["enc_feats"])
    h, positions = _embed_inputs(cfg, params, batch)
    h, _, cache = _scan_layers(cfg, params["layers"], h, positions, mode="prefill",
                               cache=None, enc_out=enc_out)
    logits = _lm_head(cfg, params, h[:, -1:])
    if pad_to is not None and cfg.has_attention and cfg.sliding_window is None:
        def grow(path_leaf):
            return path_leaf

        def grow_kv(c):
            out = dict(c)
            for k in ("k", "v"):
                if k in out and out[k].shape[2] < pad_to:
                    pad = pad_to - out[k].shape[2]
                    out[k] = jnp.pad(out[k], ((0, 0), (0, 0), (0, pad),
                                              (0, 0), (0, 0)))
            return out

        if "attn" in cache:
            cache = {**cache, "attn": grow_kv(cache["attn"])}
        elif "k" in cache:
            cache = grow_kv(cache)
    return logits, cache


def forward_decode(params: PyTree, cfg: ArchConfig, batch: dict, cache: PyTree
                   ) -> tuple[Array, PyTree]:
    """One-token decode step. batch: tokens (b,1), positions (b,),
    plus enc_out (b, S_src, d) for audio."""
    tokens = batch["tokens"]
    h = params["embed"][tokens].astype(cfg.np_dtype())
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    positions = batch["positions"]
    if cfg.pos == "learned":
        h = h + params["pos_embed"][positions][:, None].astype(h.dtype)
    enc_out = batch.get("enc_out")
    h, _, new_cache = _scan_layers(cfg, params["layers"], h, positions,
                                   mode="decode", cache=cache, enc_out=enc_out)
    logits = _lm_head(cfg, params, h)
    return logits, new_cache
