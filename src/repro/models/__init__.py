from repro.models import cnn

__all__ = ["cnn"]
