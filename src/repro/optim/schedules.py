"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, final_frac: float = 0.0):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
