"""Minimal pure-JAX optimizer library (no optax dependency offline).

An ``Optimizer`` is an (init, update) pair over arbitrary pytrees, matching
the optax calling convention so it is drop-in familiar:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

The paper's experiments use Adam(lr=1e-3, no weight decay) on clients for
EMNIST and SGD for the CIFAR/CINIC model; both are provided, plus AdamW and
gradient clipping for the large-architecture training path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


class SGDState(NamedTuple):
    momentum: PyTree


def sgd(learning_rate: float | Callable[[jax.Array], jax.Array],
        momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def lr(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "sgd": SGDState(mom)}

    def update(grads, state, params=None):
        step = state["step"]
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["sgd"].momentum, grads)
            if nesterov:
                eff = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
            else:
                eff = mom
            new_state = {"step": step + 1, "sgd": SGDState(mom)}
        else:
            eff = grads
            new_state = {"step": step + 1, "sgd": SGDState(None)}
        updates = jax.tree.map(lambda g: -lr(step) * g, eff)
        return updates, new_state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree


def adam(learning_rate: float | Callable[[jax.Array], jax.Array],
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, moment_dtype: jnp.dtype | None = None) -> Optimizer:
    """Adam / AdamW. ``moment_dtype`` (e.g. bf16) shrinks optimizer memory for
    the 100B+ configs -- recorded as a deviation in EXPERIMENTS when used."""

    def lr(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, moment_dtype or p.dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "adam": AdamState(jax.tree.map(zeros, params), jax.tree.map(zeros, params))}

    def update(grads, state, params=None):
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: (b1 * m + (1 - b1) * g).astype(m.dtype),
                          state["adam"].mu, grads)
        nu = jax.tree.map(lambda v, g: (b2 * v + (1 - b2) * jnp.square(g)).astype(v.dtype),
                          state["adam"].nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            u = -lr(step) * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p is not None:
                u = u - lr(step) * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay:
            updates = jax.tree.map(upd, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, {"step": step, "adam": AdamState(mu, nu)}

    return Optimizer(init, update)


def adamw(learning_rate, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(learning_rate, weight_decay=weight_decay, **kw)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
