"""Msgpack + zstd pytree checkpointing (no orbax/flax offline).

Pytrees of jnp/np arrays, python scalars, dicts/lists/tuples and NamedTuples
round-trip. Arrays are stored as (dtype, shape, raw bytes). Layout is a
single ``.ckpt`` file; an adjacent ``.meta.json`` carries user metadata
(round number, config digest) for cheap inspection.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(data: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 6)


def _decompress(data: bytes) -> bytes:
    if data[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "'zstandard' package is not installed")
        return zstandard.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)

PyTree = Any

_ARRAY = "__array__"
_NAMEDTUPLE = "__namedtuple__"
_TUPLE = "__tuple__"
_SCALAR = "__scalar__"


def _dtype_name(dt) -> str:
    # ml_dtypes (bfloat16 etc.) stringify by name; numpy natives by .str
    return dt.name if dt.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2") \
        else dt.str


def _dtype_from(name: str):
    if name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))
    return np.dtype(name)


def _encode(obj):
    if isinstance(obj, (jnp.ndarray, jax.Array)):
        obj = np.asarray(obj)
    if isinstance(obj, np.ndarray):
        return {_ARRAY: True, "dtype": _dtype_name(obj.dtype),
                "shape": list(obj.shape), "data": obj.tobytes()}
    if isinstance(obj, (np.integer, np.floating)):
        return {_SCALAR: True, "dtype": obj.dtype.str, "value": obj.item()}
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # NamedTuple
        return {_NAMEDTUPLE: type(obj).__name__,
                "fields": {f: _encode(v) for f, v in zip(obj._fields, obj)}}
    if isinstance(obj, tuple):
        return {_TUPLE: True, "items": [_encode(v) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v) for v in obj]
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    return obj


def _decode(obj):
    if isinstance(obj, dict):
        if obj.get(_ARRAY):
            arr = np.frombuffer(obj["data"], dtype=_dtype_from(obj["dtype"]))
            return jnp.asarray(arr.reshape(obj["shape"]))
        if obj.get(_SCALAR):
            return np.dtype(obj["dtype"]).type(obj["value"])
        if _NAMEDTUPLE in obj:  # decoded as plain dict (type identity not kept)
            return {f: _decode(v) for f, v in obj["fields"].items()}
        if obj.get(_TUPLE):
            return tuple(_decode(v) for v in obj["items"])
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save_pytree(path: str, tree: PyTree, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    packed = msgpack.packb(_encode(tree), use_bin_type=True)
    with open(path, "wb") as f:
        f.write(_compress(packed))
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def load_pytree(path: str) -> PyTree:
    with open(path, "rb") as f:
        packed = _decompress(f.read())
    return _decode(msgpack.unpackb(packed, raw=False))


def save_trainer(path: str, trainer, extra: dict | None = None) -> None:
    """Checkpoint a FedAvg/Astraea trainer: params + round + traffic."""
    meta = {"round": trainer._round, "traffic_mb": trainer.comm.megabytes}
    meta.update(extra or {})
    save_pytree(path, {"params": trainer.params, "round": trainer._round,
                       "traffic_bytes": trainer.comm.total_bytes}, meta)


def load_trainer(path: str, trainer):
    state = load_pytree(path)
    trainer.params = state["params"]
    trainer._round = int(state["round"])
    trainer.comm.total_bytes = float(state["traffic_bytes"])
    return trainer
