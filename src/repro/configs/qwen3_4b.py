"""qwen3-4b [dense] -- 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B family]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", arch_type="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936,
    qk_norm=True, tie_embeddings=True,
    rope_theta=1e6,
)
