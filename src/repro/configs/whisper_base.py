"""whisper-base [audio] -- 6L enc + 6L dec, d_model=512 8H (MHA) d_ff=2048
vocab=51865; encoder-decoder with LayerNorm + learned positions; the
mel-spectrogram + conv frontend is a STUB (the encoder consumes precomputed
frame embeddings, per the brief). source positions padded 1500->1536 for
tiling alignment. [arXiv:2212.04356]

decode_32k note: Whisper's decoder is natively capped at 448 positions; the
32k-deep cache is exercised *structurally* (the brief's shape grid), with
learned positions sized to the cache. long_500k: skipped (full attention).
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", arch_type="audio",
    n_layers=6, encoder_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865,
    norm="ln", pos="learned", tie_embeddings=True,
    source_positions=1536,
    blockwise_train=False,   # §Perf H9: dense 4k-train scores fit; blockwise streaming was a measured -20%
)
