"""Shared config machinery: input shapes, ShapeDtypeStruct specs, reduction."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ArchConfig, init_cache

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "reduced", "input_specs",
           "make_batch"]


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, d_model: int = 256) -> ArchConfig:
    """The CPU smoke variant: 2 layers, d_model<=512, <=4 experts -- same family."""
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, n_heads) if n_heads else 0
    upd = dict(
        n_layers=2, d_model=d_model,
        n_heads=n_heads, n_kv_heads=max(kv, 1) if n_heads else 0,
        head_dim=64 if cfg.n_heads else None,
        d_ff=max(cfg.d_ff // 16, 64) if not cfg.is_moe else 128,
        vocab=512,
        n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
        moe_group=64,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_head_dim=32 if cfg.ssm_heads else cfg.ssm_head_dim,
        encoder_layers=2 if cfg.encoder_layers else 0,
        source_positions=64 if cfg.encoder_layers else cfg.source_positions,
        vision_tokens=16 if cfg.vision_tokens else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        dtype="float32", remat=False,
        name=cfg.name + "-smoke",
    )
    return dataclasses.replace(cfg, **upd)


def _token_split(cfg: ArchConfig, shape: InputShape) -> tuple[int, int]:
    """(text_tokens, modality_tokens) so that total seq == shape.seq_len."""
    if cfg.arch_type == "vlm":
        v = min(cfg.vision_tokens, shape.seq_len // 2)
        return shape.seq_len - v, v
    return shape.seq_len, 0


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    No allocation -- safe for .lower() with 512 placeholder devices.
    """
    B = shape.global_batch
    i32 = jnp.int32
    dt = cfg.np_dtype()
    text, vis = _token_split(cfg, shape)
    sd = jax.ShapeDtypeStruct

    if shape.kind == "train":
        batch = {"tokens": sd((B, text), i32), "labels": sd((B, text), i32)}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = sd((B, vis, cfg.d_model), dt)
        if cfg.arch_type == "audio":
            batch["enc_feats"] = sd((B, cfg.source_positions, cfg.d_model), dt)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": sd((B, text), i32)}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = sd((B, vis, cfg.d_model), dt)
        if cfg.arch_type == "audio":
            batch["enc_feats"] = sd((B, cfg.source_positions, cfg.d_model), dt)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": sd((B, 1), i32), "positions": sd((B,), i32)}
    if cfg.arch_type == "audio":
        batch["enc_out"] = sd((B, cfg.source_positions, cfg.d_model), dt)
    cache = jax.eval_shape(lambda: init_cache(cfg, B, shape.seq_len))
    cache = jax.tree.map(lambda x: sd(x.shape, x.dtype), cache)
    return {"batch": batch, "cache": cache}


def make_batch(cfg: ArchConfig, shape: InputShape, seed: int = 0) -> dict:
    """Real (small!) arrays matching input_specs -- for smoke tests only."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)

    def realize(path_spec):
        if jnp.issubdtype(path_spec.dtype, jnp.integer):
            return jnp.zeros(path_spec.shape, path_spec.dtype)
        return jnp.ones(path_spec.shape, path_spec.dtype) * 0.01

    out = jax.tree.map(realize, specs)
    if "batch" in out and "tokens" in out["batch"]:
        tok = jax.random.randint(key, out["batch"]["tokens"].shape, 0, cfg.vocab)
        out["batch"]["tokens"] = tok.astype(jnp.int32)
        if "labels" in out["batch"]:
            out["batch"]["labels"] = tok.astype(jnp.int32)
    return out
