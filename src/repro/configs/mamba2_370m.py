"""mamba2-370m [ssm] -- 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128 (SSD, state-space duality). headdim=64, expand=2 ->
d_inner=2048, 32 SSD heads, ngroups=1, chunk=64. [arXiv:2405.21060]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_heads=32, ssm_head_dim=64, ssm_chunk=64,
    tie_embeddings=True,
)
