"""granite-moe-3b-a800m [moe] -- 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. (The assignment line also mentions
"32 experts"; we follow the structured spec "MoE 40e top-8".)
[hf:ibm-granite/granite-3.0-1b-a400m-base family]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", arch_type="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    tie_embeddings=True,
    moe_token_parallel=True,   # §Perf H3b: replicated 512-wide experts,
                               # token groups sharded over (data, model)
)
