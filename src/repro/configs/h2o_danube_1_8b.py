"""h2o-danube-1.8b [dense] -- 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000; llama+mistral mix with sliding-window attention (window 4096)
-> sub-quadratic decode, runs long_500k. [arXiv:2401.16818]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", arch_type="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab=32000,
    sliding_window=4096,
)
