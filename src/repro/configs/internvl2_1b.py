"""internvl2-1b [vlm] -- 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT vision frontend is a STUB (patch embeddings are
inputs, per the brief); LM backbone is the Qwen2-0.5B-style decoder
(QKV bias, tied embeddings). [arXiv:2404.16821]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", arch_type="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655,
    qkv_bias=True, tie_embeddings=True,
    vision_tokens=256,            # stub ViT patch embeddings per image
    blockwise_train=False,   # §Perf H9: dense 4k-train scores fit; blockwise streaming was a measured -20%
    rope_theta=1e6,
)
