"""Architecture + input-shape registry.

``get(arch_id)`` resolves any of the 10 assigned architectures (plus the
paper's own CNN models via repro.models.cnn). ``reduced(cfg)`` returns the
CPU-smoke variant (2 layers, d_model<=512, <=4 experts) of the same family.
"""
from repro.configs.base import (ArchConfig, INPUT_SHAPES, InputShape, reduced,
                                input_specs, make_batch)
from repro.configs import (grok_1_314b, internvl2_1b, qwen1_5_110b, mamba2_370m,
                           gemma_2b, h2o_danube_1_8b, whisper_base, hymba_1_5b,
                           granite_moe_3b_a800m, qwen3_4b)

_REGISTRY = {
    "grok-1-314b": grok_1_314b.CONFIG,
    "internvl2-1b": internvl2_1b.CONFIG,
    "qwen1.5-110b": qwen1_5_110b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
    "gemma-2b": gemma_2b.CONFIG,
    "h2o-danube-1.8b": h2o_danube_1_8b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
}

ARCH_IDS = list(_REGISTRY)


def get(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _REGISTRY[arch_id]


__all__ = ["ArchConfig", "INPUT_SHAPES", "InputShape", "get", "reduced",
           "input_specs", "make_batch", "ARCH_IDS"]
