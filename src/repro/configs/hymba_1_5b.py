"""hymba-1.5b [hybrid] -- 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per layer,
sliding-window attention (1024) on the attention path -> sub-quadratic,
runs long_500k. Meta-tokens from the paper are omitted (noted in
DESIGN.md). [arXiv:2411.13676]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", arch_type="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, ssm_heads=25, ssm_head_dim=64, ssm_chunk=64,
    sliding_window=1024,
)
