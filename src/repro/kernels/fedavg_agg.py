"""Pallas TPU kernel: fused weighted FedAvg aggregation (paper Eq. 6).

The FL server reduces M mediator parameter-delta shards into one update:
``out = sum_m (w_m / sum w) * deltas[m]``. For |w| in the hundreds of GB
this is the server-side hot loop; fusing normalize+scale+accumulate and
streaming (BLOCK_M, BLOCK_N) tiles through VMEM keeps it HBM-bandwidth
bound (its roofline) with zero extra passes over the deltas.

Tiling (the Mosaic path): a 2-D grid over (128-aligned param blocks x
mediator blocks). The param axis is ``parallel`` -- independent output
columns, free to split over cores -- while the mediator axis is
``arbitrary``: grid-minor, executed sequentially per param block, with the
partial weighted sums held in an fp32 VMEM accumulator scratch that is
zeroed at the first mediator block and flushed to the (possibly bf16)
output tile at the last. Deltas may be bf16 on the wire; every multiply
and accumulate happens in fp32 ((1, BLOCK_M) x (BLOCK_M, BLOCK_N) dots
with ``preferred_element_type=f32``, targeting the MXU), so a bf16 tree
costs half the HBM traffic at fp32 accumulation precision.

The kernel carries a ``pl.CostEstimate`` (2*M*N FLOPs against one
delta-read + one out-write of HBM traffic -- arithmetic intensity ~1
FLOP/byte at fp32, firmly under the TPU ridge point, i.e. memory bound)
so the scheduler never mistakes it for compute-heavy work; the bench
harness feeds the same analytic numbers through
``roofline.kernel_roofline`` and records bound + achieved fraction in
``experiments/results/kernels.json``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 2048
DEFAULT_BLOCK_M = 8


def _kernel(w_ref, d_ref, o_ref, acc_ref):
    j = pl.program_id(1)                        # mediator block (grid-minor)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)          # (1, BLOCK_M) normalized
    tile = d_ref[...].astype(jnp.float32)       # (BLOCK_M, BLOCK_N)
    acc_ref[...] += jnp.dot(w, tile, preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def cost_estimate(m: int, n: int, delta_bytes: int, out_bytes: int
                  ) -> pl.CostEstimate:
    """Analytic cost of one aggregation launch (also the roofline terms)."""
    return pl.CostEstimate(
        flops=2 * m * n,
        transcendentals=0,
        bytes_accessed=m * n * delta_bytes + n * out_bytes + m * 4,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fedavg_agg(deltas: jax.Array, weights: jax.Array, *,
               block_m: int = DEFAULT_BLOCK_M,
               block_n: int = DEFAULT_BLOCK_N,
               interpret: bool = True) -> jax.Array:
    """deltas: (M, N); weights: (M,) raw sizes n_m. Returns (N,).

    Normalization happens here (weights enter the kernel already summing
    to 1), so zero-weight padding rows are exact no-ops and callers may
    pass raw Eq. 6 sample counts -- uniform or not.
    """
    m, n = deltas.shape
    wn = weights.astype(jnp.float32)
    wn = wn / jnp.maximum(jnp.sum(wn), 1e-12)
    bm = min(block_m, m) if m else 1
    pad_m = (-m) % bm
    pad_n = (-n) % block_n
    if pad_m or pad_n:
        deltas = jnp.pad(deltas, ((0, pad_m), (0, pad_n)))
    if pad_m:
        wn = jnp.pad(wn, (0, pad_m))
    mp, np_ = deltas.shape
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n, mp // bm),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),       # weight chunk
            pl.BlockSpec((bm, block_n), lambda i, j: (j, i)),  # delta tile
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), deltas.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        cost_estimate=cost_estimate(mp, np_, deltas.dtype.itemsize,
                                    deltas.dtype.itemsize),
        interpret=interpret,
    )(wn[None, :], deltas)
    return out[0, :n]
