"""Pallas TPU kernel: fused weighted FedAvg aggregation (paper Eq. 6).

The FL server reduces M mediator parameter-delta shards into one update:
``out = sum_m (w_m / sum w) * deltas[m]``. For |w| in the hundreds of GB
this is the server-side hot loop; fusing normalize+scale+accumulate and
streaming (M, block_n) tiles through VMEM keeps it HBM-bandwidth-bound
(its roofline) with zero extra passes.

Tiling: grid over the flattened parameter axis; each step loads an
(M, BLOCK_N) tile (bf16/f32), multiplies by the fp32 normalized weights
held in VMEM, accumulates in fp32, writes the BLOCK_N output tile.
BLOCK_N is 128-aligned for lane efficiency; M rides the sublane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048


def _kernel(w_ref, d_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)                  # (M,)
    tile = d_ref[...].astype(jnp.float32)               # (M, BLOCK_N)
    acc = jnp.einsum("m,mn->n", w, tile,
                     preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedavg_agg(deltas: jax.Array, weights: jax.Array, *,
               block_n: int = DEFAULT_BLOCK_N, interpret: bool = True) -> jax.Array:
    """deltas: (M, N); weights: (M,) raw sizes n_m. Returns (N,)."""
    m, n = deltas.shape
    wn = weights.astype(jnp.float32)
    wn = wn / jnp.maximum(jnp.sum(wn), 1e-12)
    pad = (-n) % block_n
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    np_ = deltas.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),                  # weights: whole
            pl.BlockSpec((m, block_n), lambda i: (0, i)),        # delta tile
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), deltas.dtype),
        interpret=interpret,
    )(wn, deltas)
    return out[:n]
