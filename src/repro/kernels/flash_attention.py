"""Pallas TPU kernel: blockwise-softmax (flash) attention, causal + SWA.

TPU adaptation of the memory-bound attention hot spot for the model zoo's
prefill/train path: KV is streamed through VMEM in (BLOCK_K, d) tiles with
an online softmax (running row max m, denominator l, fp32 accumulator), so
the (S x S) score matrix never exists in HBM. Block shapes are 128-aligned
for the MXU; the accumulator lives in VMEM scratch across the innermost
KV-grid dimension (standard TPU flash scheme: grid (b, h, q_blocks,
k_blocks), kv innermost, output written on the last kv step).

Supports causal masking, sliding-window masking, and a q position offset
(chunked prefill against an existing cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def cost_estimate(b: int, h: int, sq: int, skv: int, d: int,
                  io_bytes: int = 4) -> pl.CostEstimate:
    """Analytic cost of one attention launch (also the roofline terms).

    FLOPs: the two MXU contractions per tile (q k^T and p v), 2*sq*skv*d
    each over every (batch, head) pair; online-softmax elementwise work
    is O(sq*skv) noise against them. Transcendentals: one exp per score
    entry (the correction exps are O(sq) noise). HBM traffic is the
    flash-attention ideal -- one pass over q, k, v and one o write; the
    (sq, skv) score matrix never exists in HBM.
    """
    return pl.CostEstimate(
        flops=4 * b * h * sq * skv * d,
        transcendentals=b * h * sq * skv,
        bytes_accessed=io_bytes * (2 * b * h * sq * d + 2 * b * h * skv * d),
    )


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None, q_offset: int,
            block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                      # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (b, h, s, d) -- GQA kv repeated to h beforehand (ops.py)."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    n_q, n_k = sq // block_q, skv // block_k
    scale = 1.0 / np.sqrt(d)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kern,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running row max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),   # fp32 output accumulator
        ],
        cost_estimate=cost_estimate(b, h, sq, skv, d, q.dtype.itemsize),
        interpret=interpret,
    )(q, k, v)
