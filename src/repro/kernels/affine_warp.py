"""Pallas TPU kernel: fused batched bilinear affine warp (Alg. 2 ``Augment``).

One launch warps a whole ``(B, H, W, C)`` batch -- the client-side
augmentation primitive of the online rebalancing pipeline.  The old path
stacked one ``map_coordinates`` call per channel per image; here the grid
iterates over the batch and each step warps ALL channels of its image in a
single MXU contraction.

Per grid step: compute the inverse-mapped source coordinates for every
output pixel, split them into the four bilinear corners, build the sparse
``(HW, HW)`` gather matrix as a sum of four iota one-hots scaled by the
corner weights (out-of-bounds corners get weight 0 == ``mode="constant"``
zero fill), and contract it against the flattened ``(HW, C)`` image.  A
gather becomes a matmul -- the standard trick for resamplers on a systolic
array, since Mosaic has no efficient arbitrary dynamic gather.

Matches ``jax.scipy.ndimage.map_coordinates(order=1, mode="constant")``
(``kernels/ref.py::affine_warp``) to fp32 round-off; tests assert
atol 1e-5 in interpret mode.

VMEM: the gather matrix is ``HW x HW`` fp32 -- 2.5 MB at 28x28, 4 MB at
32x32.  Sized for the paper's mobile-vision inputs, not megapixel frames.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def cost_estimate(b: int, h: int, w: int, c: int,
                  img_bytes: int = 4) -> pl.CostEstimate:
    """Analytic cost of one warp launch (also the roofline terms).

    Per image the gather-matrix build touches four (HW, HW) one-hot
    planes (compare + scale + accumulate ~ 3 ops each) and the
    contraction is a (HW, HW) x (HW, C) matmul; coordinate math is
    O(HW) noise. HBM traffic is one image read + one image write plus
    the tiny affine parameters -- the (HW, HW) gather matrix never
    leaves VMEM, which is the whole point of the fusion.
    """
    hw = h * w
    return pl.CostEstimate(
        flops=b * (2 * hw * hw * c + 12 * hw * hw),
        transcendentals=0,
        bytes_accessed=b * (2 * hw * c * img_bytes + 4 * 4 + 2 * 4),
    )


def _kernel(mat_ref, trans_ref, img_ref, o_ref):
    _, h, w, c = img_ref.shape
    mat = mat_ref[0]                                    # (2, 2)
    tr = trans_ref[0]                                   # (2,)
    img = img_ref[0]                                    # (H, W, C)
    iy = jax.lax.broadcasted_iota(jnp.float32, (h, w), 0)
    ix = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1)
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    dy, dx = iy - cy, ix - cx
    sy = mat[0, 0] * dy + mat[0, 1] * dx + cy + tr[0]   # source row coord
    sx = mat[1, 0] * dy + mat[1, 1] * dx + cx + tr[1]   # source col coord
    y0, x0 = jnp.floor(sy), jnp.floor(sx)
    fy, fx = sy - y0, sx - x0
    hw = h * w
    q = jax.lax.broadcasted_iota(jnp.int32, (hw, hw), 1)
    gather = jnp.zeros((hw, hw), jnp.float32)
    for oy, ox in ((0, 0), (0, 1), (1, 0), (1, 1)):
        yy, xx = y0 + oy, x0 + ox
        wgt = (fy if oy else 1.0 - fy) * (fx if ox else 1.0 - fx)
        valid = (yy >= 0) & (yy <= h - 1) & (xx >= 0) & (xx <= w - 1)
        wgt = jnp.where(valid, wgt, 0.0).reshape(hw, 1)
        src = (jnp.clip(yy, 0, h - 1) * w
               + jnp.clip(xx, 0, w - 1)).astype(jnp.int32).reshape(hw, 1)
        gather = gather + wgt * (q == src).astype(jnp.float32)
    out = jnp.dot(gather, img.reshape(hw, c).astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[0] = out.reshape(h, w, c).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def affine_warp(images: jax.Array, mats: jax.Array, trans: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """images (B, H, W, C); mats (B, 2, 2); trans (B, 2) -> (B, H, W, C)."""
    b, h, w, c = images.shape
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 2, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(images.shape, images.dtype),
        cost_estimate=cost_estimate(b, h, w, c, images.dtype.itemsize),
        interpret=interpret,
    )(mats.astype(jnp.float32), trans.astype(jnp.float32), images)
