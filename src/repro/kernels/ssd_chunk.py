"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block (arXiv:2405.21060).

The chunked SSD algorithm (models/ssm.py) spends its FLOPs in the
per-chunk, per-head computation:

    dA        = dt * A[h]                      (L,)
    seg(i,j)  = sum dA[j+1..i]  (tril)         (L, L)
    y_diag    = (C B^T  o  exp(seg)) (dt * x)  (L, p)
    S_chunk   = (B * dt * decay_to_end)^T x    (n, p)   outgoing state
    g_chunk   = exp(sum dA)                    ()       chunk decay

which is matmul-rich and embarrassingly parallel over (batch, chunk,
head) -- exactly one VMEM tile each. This kernel fuses the whole block:
the (L, L) decay matrix never leaves VMEM, scores/decay/masking fuse into
the two MXU matmuls. The sequential inter-chunk recurrence (a tiny
(h, p, n) state update) stays in XLA (lax.scan), as does y_off.

Tile shapes: L (chunk) = 64..128, p (head dim) = 64, n (state) = 128 --
all MXU-aligned for mamba2-370m. fp32 accumulation throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def cost_estimate(b: int, nc: int, L: int, h: int, p: int, n: int,
                  io_bytes: int = 4) -> pl.CostEstimate:
    """Analytic cost of one SSD launch (also the roofline terms).

    Per (batch, chunk, head) tile: three MXU contractions -- C B^T
    (2 L^2 n), masked scores x dx (2 L^2 p), and the outgoing-state
    w^T x (2 L n p) -- plus ~3 L^2 elementwise for the decay mask and
    score scaling. Transcendentals: exp over the (L, L) segment-decay
    matrix plus the L decay-to-end terms and the chunk gate.
    HBM traffic: B and C are shared across heads but re-fetched per
    grid step (grid is (b, nc, h)), so they are charged h times; S is
    always written fp32.
    """
    tiles = b * nc * h
    return pl.CostEstimate(
        flops=tiles * (2 * L * L * (n + p) + 2 * L * n * p + 3 * L * L),
        transcendentals=tiles * (L * L + L + 1),
        bytes_accessed=tiles * (2 * L * p * io_bytes      # x read + y write
                                + L * io_bytes + 4        # dt, A[h]
                                + 2 * L * n * io_bytes    # B, C
                                + n * p * 4 + 4),         # S, g (fp32)
    )


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, g_ref):
    # blocks: x (L, p); dt (L,); a (1,); b, c (L, n)
    x = x_ref[...].astype(jnp.float32)
    dt = dt_ref[...].astype(jnp.float32)                    # (L,)
    a = a_ref[0].astype(jnp.float32)                        # scalar
    B = b_ref[...].astype(jnp.float32)                      # (L, n)
    C = c_ref[...].astype(jnp.float32)

    L = x.shape[0]
    dA = dt * a                                             # (L,)
    cum = jnp.cumsum(dA)                                    # (L,)
    seg = cum[:, None] - cum[None, :]                       # (L, L) sum (j, i]
    tril = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tril, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    dx = dt[:, None] * x                                    # (L, p)
    y = jax.lax.dot_general(scores * decay, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (L, p)

    decay_to_end = jnp.exp(cum[-1] - cum)                   # (L,)
    w = (decay_to_end * dt)[:, None] * B                    # (L, n)
    S = jax.lax.dot_general(w, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (n, p)

    y_ref[...] = y.astype(y_ref.dtype)
    s_ref[...] = S.astype(s_ref.dtype)
    g_ref[...] = jnp.exp(cum[-1]).astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
              C: jax.Array, *, interpret: bool = True):
    """Fused intra-chunk SSD for all (batch, chunk, head) tiles.

    Args:
      x:  (b, nc, L, h, p)  pre-activation inputs per head.
      dt: (b, nc, L, h)     positive step sizes.
      A:  (h,)              negative decay rates.
      B, C: (b, nc, L, n)   shared across heads (ngroups=1).

    Returns:
      y_diag: (b, nc, L, h, p), S_chunk: (b, nc, h, n, p), g: (b, nc, h).
    """
    b, nc, L, h, p = x.shape
    n = B.shape[-1]

    grid = (b, nc, h)
    y, S, g = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, L, None, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((None, None, L, None), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1,), lambda bi, ci, hi: (hi,)),
            pl.BlockSpec((None, None, L, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((None, None, L, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, L, None, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((None, None, None, n, p),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((None, None, None), lambda bi, ci, hi: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, L, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
        ],
        cost_estimate=cost_estimate(b, nc, L, h, p, n, x.dtype.itemsize),
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, S, g


