"""Pallas TPU kernel: batched KLD-to-uniform scoring (paper Alg. 3 line 7).

The greedy rescheduler evaluates, for one mediator histogram P_m and every
unassigned client histogram P_k, ``D_KL(normalize(P_m + P_k) || U)``. With
K clients and C classes this is a (K, C) sweep repeated O(c^2) times per
scheduling pass; the kernel fuses merge + normalize + xlogx + reduce in one
VMEM pass over (BLOCK_K, C) tiles.

D_KL(p || U) = sum_i p_i * (log p_i + log C); 0*log0 := 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 256


def _kernel(m_ref, c_ref, o_ref, *, log_c: float):
    med = m_ref[...].astype(jnp.float32)                # (1, C)
    cli = c_ref[...].astype(jnp.float32)                # (BLOCK_K, C)
    merged = med + cli
    total = jnp.maximum(jnp.sum(merged, axis=-1, keepdims=True), 1e-12)
    p = merged / total
    terms = jnp.where(p > 0, p * (jnp.log(jnp.maximum(p, 1e-12)) + log_c), 0.0)
    o_ref[...] = jnp.sum(terms, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def kld_score(mediator_counts: jax.Array, client_counts: jax.Array, *,
              block_k: int = DEFAULT_BLOCK_K, interpret: bool = True) -> jax.Array:
    """mediator_counts: (C,); client_counts: (K, C). Returns (K,) fp32."""
    k, c = client_counts.shape
    pad = (-k) % block_k
    if pad:
        client_counts = jnp.pad(client_counts, ((0, pad), (0, 0)))
    kp = client_counts.shape[0]
    out = pl.pallas_call(
        functools.partial(_kernel, log_c=float(np.log(c))),
        grid=(kp // block_k,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((block_k, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((kp,), jnp.float32),
        interpret=interpret,
    )(mediator_counts[None, :], client_counts)
    return out[:k]
