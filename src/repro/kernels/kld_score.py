"""Pallas TPU kernels for the Alg. 3 KLD rescheduling sweep (paper line 7).

The greedy rescheduler evaluates, for a mediator histogram P_m and every
unassigned client histogram P_k, ``D_KL(normalize(P_m + P_k) || U)``.
Three entry points, from primitive to fully fused:

* ``kld_score``      -- one mediator vs (K, C) candidates -> (K,). The
  historical per-step sweep; one launch per greedy step when driven from
  ``scheduling.reschedule(impl="loop", use_kernel=True)``.
* ``kld_score_matrix`` -- the full (M, K, C) mediator x client sweep in
  ONE launch -> (M, K). Grid tiles (BLOCK_M mediators x BLOCK_K clients);
  each step materializes the (BLOCK_M, BLOCK_K, C) merged histograms in
  VMEM and reduces over C. Replaces the O(M) per-mediator launches when
  scoring many open mediators at once (diagnostics, placement sweeps).
* ``kld_greedy_picks`` -- the ENTIRE Alg. 3 scheduling pass in one
  launch. Grid = (K absorption steps x K/BLOCK_K candidate blocks), both
  ``arbitrary`` (sequential); VMEM scratch carries the open mediator's
  histogram, the picked-client mask (as a 0/+inf additive score mask) and
  the running (min, argmin, winning row); SMEM carries the fill counter.
  Each step sweeps every candidate block, combines block argmins with
  strict-< (first-minimum tie-break, the numpy loop's semantics), emits
  the picked client id, folds its histogram into the mediator and resets
  it every ``gamma`` picks. O(1) ``pallas_call``s per scheduling pass vs
  the historical O(M*gamma) -- this is what lets rescheduling scale past
  1e5 clients without a host roundtrip per absorbed client.

Score arithmetic is an op-for-op replica of
``distribution.merged_kld_scores`` in f32 (same adds, same normalize, same
``log(max(p, eps)) - log(max(q, eps))`` ratio, same masked row-sum), so
picks are bitwise-comparable against the numpy loop oracle -- property-
tested, ties included, in tests/test_scheduling.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
DEFAULT_BLOCK_M = 8

_EPS = 1e-12


def _score_rows(med: jax.Array, cli: jax.Array) -> jax.Array:
    """D_KL(normalize(med + cli_k) || U) per row; exact replica of
    ``distribution.merged_kld_scores`` (f32, same op order)."""
    c = cli.shape[-1]
    merged = med + cli                                   # (..., C)
    total = jnp.sum(merged, axis=-1, keepdims=True)
    p = merged / jnp.maximum(total, _EPS)
    q = jnp.full((c,), 1.0 / c, jnp.float32)
    ratio = jnp.log(jnp.maximum(p, _EPS)) - jnp.log(jnp.maximum(q, _EPS))
    return jnp.sum(jnp.where(p > 0, p * ratio, 0.0), axis=-1)


def score_cost(m: int, k: int, c: int) -> pl.CostEstimate:
    """Analytic cost of an (M, K, C) scoring sweep (one fused launch)."""
    return pl.CostEstimate(
        flops=6 * m * k * c,              # add, sum, div, mul, select, reduce
        transcendentals=m * k * c,        # log per merged bin
        bytes_accessed=(m * c + k * c) * 4 + m * k * 4,
    )


# ----------------------------------------------------------------------
# kld_score: one mediator row, (K, C) candidates -> (K,)
# ----------------------------------------------------------------------

def _score_kernel(m_ref, c_ref, o_ref):
    med = m_ref[...].astype(jnp.float32)                # (1, C)
    cli = c_ref[...].astype(jnp.float32)                # (BLOCK_K, C)
    o_ref[...] = _score_rows(med, cli)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def kld_score(mediator_counts: jax.Array, client_counts: jax.Array, *,
              block_k: int = DEFAULT_BLOCK_K, interpret: bool = True) -> jax.Array:
    """mediator_counts: (C,); client_counts: (K, C). Returns (K,) fp32."""
    k, c = client_counts.shape
    pad = (-k) % block_k
    if pad:
        client_counts = jnp.pad(client_counts, ((0, pad), (0, 0)))
    kp = client_counts.shape[0]
    out = pl.pallas_call(
        _score_kernel,
        grid=(kp // block_k,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((block_k, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((kp,), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        cost_estimate=score_cost(1, kp, c),
        interpret=interpret,
    )(mediator_counts[None, :], client_counts)
    return out[:k]


# ----------------------------------------------------------------------
# kld_score_matrix: full (M, K, C) sweep in one launch -> (M, K)
# ----------------------------------------------------------------------

def _matrix_kernel(m_ref, c_ref, o_ref):
    med = m_ref[...].astype(jnp.float32)                # (BLOCK_M, C)
    cli = c_ref[...].astype(jnp.float32)                # (BLOCK_K, C)
    o_ref[...] = _score_rows(med[:, None, :], cli[None, :, :])


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def kld_score_matrix(mediator_counts: jax.Array, client_counts: jax.Array, *,
                     block_m: int = DEFAULT_BLOCK_M,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True) -> jax.Array:
    """mediator_counts: (M, C); client_counts: (K, C). Returns (M, K) fp32.

    One launch over the whole mediator x client histogram matrix -- the
    fused replacement for M independent ``kld_score`` launches.
    """
    m, c = mediator_counts.shape
    k, _ = client_counts.shape
    bm = min(block_m, max(m, 1))
    bk = min(block_k, max(k, 1))
    pad_m = (-m) % bm
    pad_k = (-k) % bk
    if pad_m:
        mediator_counts = jnp.pad(mediator_counts, ((0, pad_m), (0, 0)))
    if pad_k:
        client_counts = jnp.pad(client_counts, ((0, pad_k), (0, 0)))
    mp, kp = mediator_counts.shape[0], client_counts.shape[0]
    out = pl.pallas_call(
        _matrix_kernel,
        grid=(mp // bm, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        cost_estimate=score_cost(mp, kp, c),
        interpret=interpret,
    )(mediator_counts, client_counts)
    return out[:m, :k]


# ----------------------------------------------------------------------
# kld_greedy_picks: the whole Alg. 3 pass in one launch -> (K,) picks
# ----------------------------------------------------------------------

def _greedy_kernel(c_ref, o_ref, mask_ref, med_ref, hist_ref, fill_ref,
                   best_ref, bidx_ref, *, k, gamma, block_k):
    s, b = pl.program_id(0), pl.program_id(1)

    @pl.when(s == 0)
    def _():                         # first step: build the additive score
        base = b * block_k           # mask -- 0 for live rows, +inf for
        mask_ref[pl.ds(base, block_k)] = jnp.where(     # padding rows
            base + jax.lax.iota(jnp.int32, block_k) < k, 0.0, jnp.inf)

        @pl.when(b == 0)
        def _():                     # scratch is NOT zero-initialized
            med_ref[...] = jnp.zeros_like(med_ref)
            fill_ref[0] = 0

    @pl.when(b == 0)
    def _():                         # new absorption step: reset the
        best_ref[0] = jnp.inf        # running argmin, and open a fresh
        bidx_ref[0] = 0              # mediator once the last one filled

        @pl.when(fill_ref[0] == gamma)
        def _():
            med_ref[...] = jnp.zeros_like(med_ref)
            fill_ref[0] = 0

    cli = c_ref[...]                                     # (BLOCK_K, C) f32
    scores = _score_rows(med_ref[0, :][None, :], cli)
    masked = scores + mask_ref[pl.ds(b * block_k, block_k)]
    bmin = jnp.min(masked)
    barg = jnp.argmin(masked).astype(jnp.int32)          # first minimum

    @pl.when(bmin < best_ref[0])     # strict <: earlier blocks win ties,
    def _():                         # matching the loop's first-minimum
        best_ref[0] = bmin
        bidx_ref[0] = b * block_k + barg
        hist_ref[...] = jax.nn.one_hot(barg, block_k, dtype=jnp.float32
                                       )[None, :] @ cli

    @pl.when(b == pl.num_programs(1) - 1)
    def _():                         # sweep done: commit the pick
        pick = bidx_ref[0]
        o_ref[0] = pick
        mask_ref[pl.ds(pick, 1)] = jnp.full((1,), jnp.inf)
        med_ref[...] += hist_ref[...]
        fill_ref[0] += 1


def greedy_cost(k: int, c: int) -> pl.CostEstimate:
    """K absorption steps, each a full (K, C) scoring sweep."""
    sweep = score_cost(1, k, c)
    return pl.CostEstimate(
        flops=k * sweep.flops + 4 * k * k,   # + mask/min/argmin combines
        transcendentals=k * sweep.transcendentals,
        bytes_accessed=k * k * c * 4 + k * 4,
    )


@functools.partial(jax.jit, static_argnames=("gamma", "block_k", "interpret"))
def kld_greedy_picks(client_counts: jax.Array, gamma: int, *,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True) -> jax.Array:
    """One-launch Alg. 3: client_counts (K, C) -> (K,) int32 picks.

    Returns the absorption order: mediator ``i`` holds clients
    ``picks[i*gamma : (i+1)*gamma]``. Bitwise-identical to the numpy
    greedy loop (``scheduling.reschedule(impl="loop")``), ties included.
    The (K, C) histogram matrix stays tiled in HBM; per-step VMEM
    residency is one (BLOCK_K, C) tile plus the (K,) pick mask.
    """
    kk, c = client_counts.shape
    bk = min(block_k, max(kk, 1))
    pad = (-kk) % bk
    if pad:
        client_counts = jnp.pad(client_counts, ((0, pad), (0, 0)))
    kp = client_counts.shape[0]
    return pl.pallas_call(
        functools.partial(_greedy_kernel, k=kk, gamma=gamma, block_k=bk),
        grid=(kk, kp // bk),
        in_specs=[pl.BlockSpec((bk, c), lambda s, b: (b, 0))],
        out_specs=pl.BlockSpec((1,), lambda s, b: (s,)),
        out_shape=jax.ShapeDtypeStruct((kk,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((kp,), jnp.float32),     # pick mask (0 / +inf)
            pltpu.VMEM((1, c), jnp.float32),    # open mediator histogram
            pltpu.VMEM((1, c), jnp.float32),    # winning candidate row
            pltpu.SMEM((1,), jnp.int32),        # mediator fill counter
            pltpu.SMEM((1,), jnp.float32),      # running min score
            pltpu.SMEM((1,), jnp.int32),        # running argmin
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        cost_estimate=greedy_cost(kk, c),
        interpret=interpret,
    )(client_counts.astype(jnp.float32))
