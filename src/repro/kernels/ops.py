"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode --
the kernel body runs in Python with identical semantics; on a real TPU the
same calls compile through Mosaic. ``interpret`` auto-detects the backend.

``fedavg_agg_tree`` applies the aggregation kernel to whole parameter
pytrees (the FL server path); ``flash_attention`` accepts model-layout
(b, s, h, d) tensors with GQA kv heads and handles the repeat + transpose.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import fedavg_agg as _fa
from repro.kernels import flash_attention as _fl
from repro.kernels import kld_score as _kl
from repro.kernels import ssd_chunk as _sc

PyTree = Any


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fedavg_agg(deltas: jax.Array, weights: jax.Array, **kw) -> jax.Array:
    """deltas (M, N) + sizes (M,) -> weighted average (N,)."""
    kw.setdefault("interpret", _interpret())
    return _fa.fedavg_agg(deltas, weights, **kw)


def fedavg_agg_tree(deltas_tree: PyTree, weights: jax.Array, **kw) -> PyTree:
    """Apply Eq. 6 leafwise to a stacked (M, ...) parameter pytree."""
    def leaf(d):
        m = d.shape[0]
        flat = d.reshape(m, -1)
        return fedavg_agg(flat, weights, **kw).reshape(d.shape[1:])
    return jax.tree.map(leaf, deltas_tree)


def kld_score(mediator_counts: jax.Array, client_counts: jax.Array, **kw) -> jax.Array:
    kw.setdefault("interpret", _interpret())
    return _kl.kld_score(mediator_counts, client_counts, **kw)


def ssd_chunk(x, dt, A, B, C, **kw):
    """Fused Mamba-2 intra-chunk block: see kernels/ssd_chunk.py."""
    kw.setdefault("interpret", _interpret())
    return _sc.ssd_chunk(x, dt, A, B, C, **kw)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, **kw) -> jax.Array:
    """Model layout: q (b, s, H, d); k, v (b, s, KV, d). Returns (b, s, H, d)."""
    kw.setdefault("interpret", _interpret())
    n_rep = q.shape[2] // k.shape[2]
    if n_rep > 1:
        b, s, kv, hd = k.shape
        k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                             ).reshape(b, s, kv * n_rep, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b, s, kv, n_rep, hd)
                             ).reshape(b, s, kv * n_rep, hd)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fl.flash_attention(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, **kw)
    return jnp.swapaxes(out, 1, 2)
