"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode --
the kernel body runs in Python with identical semantics; on a real TPU the
same calls compile through Mosaic. ``interpret`` auto-detects the backend.

``fedavg_agg_tree`` applies the aggregation kernel to whole parameter
pytrees (the FL server path); ``flash_attention`` accepts model-layout
(b, s, h, d) tensors with GQA kv heads and handles the repeat + transpose.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import affine_warp as _aw
from repro.kernels import fedavg_agg as _fa
from repro.kernels import flash_attention as _fl
from repro.kernels import kld_score as _kl
from repro.kernels import ssd_chunk as _sc

PyTree = Any


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fedavg_agg(deltas: jax.Array, weights: jax.Array, **kw) -> jax.Array:
    """deltas (M, N) + sizes (M,) -> weighted average (N,)."""
    kw.setdefault("interpret", _interpret())
    return _fa.fedavg_agg(deltas, weights, **kw)


def fedavg_agg_tree(deltas_tree: PyTree, weights: jax.Array, *,
                    fuse: bool | None = None, **kw) -> PyTree:
    """Apply Eq. 6 to a stacked (M, ...) parameter pytree.

    ``fuse=True`` (the default) flattens the leaves into one
    ``(M, total_params)`` buffer per dtype and runs a single kernel launch
    over each -- one grid, one pass over HBM, no per-leaf ragged tails
    (ROADMAP "kernel aggregation at scale"). Each column is reduced
    independently with the same (BLOCK_M) accumulation chunking, so the
    result is bitwise identical to the per-leaf path; grouping by dtype
    keeps every leaf's reduction in its own wire dtype (a bf16/f32 mixed
    tree costs two launches, never a promotion). Normalization happens
    inside ``fedavg_agg``, so non-uniform Eq. 6 weights take the fused
    path exactly like uniform ones. ``fuse=False`` keeps the historical
    one-launch-per-leaf path (the equivalence oracle).
    """
    kw.setdefault("interpret", _interpret())
    if fuse is None:
        fuse = True
    if not fuse:
        def leaf(d):
            m = d.shape[0]
            flat = d.reshape(m, -1)
            return fedavg_agg(flat, weights, **kw).reshape(d.shape[1:])
        return jax.tree.map(leaf, deltas_tree)
    leaves, treedef = jax.tree.flatten(deltas_tree)
    if not leaves:
        # rank-0 LoRA adapter trees are legitimately empty: aggregating
        # nothing is the identity, not an error
        return deltas_tree
    m = leaves[0].shape[0]
    by_dtype: dict[Any, list[int]] = {}
    for i, l in enumerate(leaves):
        by_dtype.setdefault(l.dtype, []).append(i)
    outs: list[Any] = [None] * len(leaves)
    for dt, idxs in by_dtype.items():
        flat = jnp.concatenate([leaves[i].reshape(m, -1) for i in idxs],
                               axis=1)
        agg = fedavg_agg(flat, weights, **kw)           # (group_params,)
        start = 0
        for i in idxs:
            size = int(np.prod(leaves[i].shape[1:]))
            outs[i] = agg[start:start + size].reshape(leaves[i].shape[1:])
            start += size
    return jax.tree.unflatten(treedef, outs)


def affine_warp(images: jax.Array, mats: jax.Array, trans: jax.Array,
                **kw) -> jax.Array:
    """Fused batched bilinear warp: images (B, H, W, C), inverse-map mats
    (B, 2, 2), translations (B, 2) -- the Alg. 2 augmentation primitive."""
    kw.setdefault("interpret", _interpret())
    return _aw.affine_warp(images, mats, trans, **kw)


def kld_score(mediator_counts: jax.Array, client_counts: jax.Array, **kw) -> jax.Array:
    """One mediator (C,) vs candidates (K, C) -> (K,) Alg. 3 scores."""
    kw.setdefault("interpret", _interpret())
    return _kl.kld_score(mediator_counts, client_counts, **kw)


def kld_score_matrix(mediator_counts: jax.Array, client_counts: jax.Array,
                     **kw) -> jax.Array:
    """Fused (M, K, C) sweep: mediators (M, C) x clients (K, C) -> (M, K)
    scores in ONE launch (vs M per-mediator ``kld_score`` launches)."""
    kw.setdefault("interpret", _interpret())
    return _kl.kld_score_matrix(mediator_counts, client_counts, **kw)


def kld_greedy_picks(client_counts: jax.Array, gamma: int, **kw) -> jax.Array:
    """The whole Alg. 3 scheduling pass in one launch: (K, C) histograms
    -> (K,) absorption order, bitwise-identical to the greedy loop."""
    kw.setdefault("interpret", _interpret())
    return _kl.kld_greedy_picks(client_counts, gamma, **kw)


def ssd_chunk(x, dt, A, B, C, **kw):
    """Fused Mamba-2 intra-chunk block: see kernels/ssd_chunk.py."""
    kw.setdefault("interpret", _interpret())
    return _sc.ssd_chunk(x, dt, A, B, C, **kw)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, **kw) -> jax.Array:
    """Model layout: q (b, s, H, d); k, v (b, s, KV, d). Returns (b, s, H, d)."""
    kw.setdefault("interpret", _interpret())
    n_rep = q.shape[2] // k.shape[2]
    if n_rep > 1:
        b, s, kv, hd = k.shape
        k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                             ).reshape(b, s, kv * n_rep, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b, s, kv, n_rep, hd)
                             ).reshape(b, s, kv * n_rep, hd)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fl.flash_attention(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, **kw)
    return jnp.swapaxes(out, 1, 2)
