"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def fedavg_agg(deltas: Array, weights: Array) -> Array:
    """Eq. 6: out[n] = sum_m (w_m / sum w) * deltas[m, n].  fp32 accumulate."""
    w = weights.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    return jnp.einsum("m,mn->n", wn, deltas.astype(jnp.float32)).astype(deltas.dtype)


def affine_warp(images: Array, mats: Array, trans: Array, *,
                order: int = 1) -> Array:
    """Batched inverse-mapped affine warp, the ``map_coordinates`` oracle.

    ``images (B, H, W, C)``; ``mats (B, 2, 2)`` inverse maps (output grid ->
    input coords, about the image center); ``trans (B, 2)`` translations.
    Bilinear (``order=1``) with ``mode="constant"`` zero fill -- the exact
    semantics the fused Pallas kernel (kernels/affine_warp.py) reproduces.
    """
    def one(img, mat, tr):
        h, w, c = img.shape
        yy, xx = jnp.meshgrid(jnp.arange(h, dtype=jnp.float32),
                              jnp.arange(w, dtype=jnp.float32), indexing="ij")
        cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
        src = jnp.tensordot(mat, jnp.stack([yy - cy, xx - cx]), axes=1)
        sy = src[0] + cy + tr[0]
        sx = src[1] + cx + tr[1]
        return jnp.stack(
            [jax.scipy.ndimage.map_coordinates(img[..., i], [sy, sx],
                                               order=order, mode="constant")
             for i in range(c)], axis=-1)

    return jax.vmap(one)(images, mats, trans)


def kld_score(mediator_counts: Array, client_counts: Array) -> Array:
    """Alg. 3 scores: D_KL(normalize(P_m + P_k) || U) for each candidate k."""
    merged = mediator_counts[None, :].astype(jnp.float32) + client_counts.astype(jnp.float32)
    total = jnp.maximum(merged.sum(-1, keepdims=True), 1e-12)
    p = merged / total
    c = merged.shape[-1]
    terms = jnp.where(p > 0, p * (jnp.log(jnp.maximum(p, 1e-12)) + np.log(c)), 0.0)
    return terms.sum(-1)


def kld_score_matrix(mediator_counts: Array, client_counts: Array) -> Array:
    """(M, C) mediators x (K, C) clients -> (M, K) Alg. 3 scores."""
    return jax.vmap(lambda m: kld_score(m, client_counts))(
        mediator_counts.astype(jnp.float32))


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None, q_offset: int = 0) -> Array:
    """Reference attention. q,k,v: (b, h, s, d) (kernel layout). fp32 softmax."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ssd_chunk(x, dt, A, B, C):
    """Oracle for the fused intra-chunk SSD kernel (pure jnp, fp32).

    x (b,nc,L,h,p); dt (b,nc,L,h); A (h,); B,C (b,nc,L,n).
    Returns (y_diag, S_chunk (b,nc,h,n,p), g (b,nc,h)).
    """
    f32 = jnp.float32
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    Af = A.astype(f32)
    Bf = B.astype(f32)
    Cf = C.astype(f32)
    dA = dtf * Af                                       # (b,nc,L,h)
    cum = jnp.cumsum(dA, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,L,L,h)
    L = x.shape[2]
    tril = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tril[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)
    dx = dtf[..., None] * xf
    y = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, dx)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    S = jnp.einsum("bcln,bclh,bclhp->bchnp", Bf, decay_to_end * dtf, xf)
    g = jnp.exp(cum[:, :, -1, :])
    return y.astype(x.dtype), S, g
