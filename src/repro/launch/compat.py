"""Cross-version jax API shims (0.4.x <-> 0.5+).

Three APIs this codebase leans on moved between jax releases:

* ``shard_map``: ``jax.experimental.shard_map.shard_map(..., auto=...)``
  (0.4.x) became ``jax.shard_map(..., axis_names=..., check_vma=...)``.
  ``shard_map`` here takes the *manual* axis names and translates.
* ``jax.set_mesh`` (0.5+) vs the classic ``with mesh:`` context (0.4.x).
* ``AbstractMesh((sizes), (names))`` (0.5+) vs
  ``AbstractMesh(((name, size), ...))`` (0.4.x).

Everything engine/launch-side goes through these so the same code lowers on
both toolchains.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Any

import jax

__all__ = ["shard_map", "use_mesh", "abstract_mesh"]


def shard_map(f, mesh, in_specs, out_specs, *, manual_axes: tuple | None = None,
              check: bool = True):
    """``shard_map`` with *manual_axes* semantics on any jax version.

    ``manual_axes=None`` means fully manual (every mesh axis). ``check``
    maps to ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        kw: dict[str, Any] = {"check_vma": check}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if manual_axes is not None:
        auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def use_mesh(mesh):
    """Context manager making *mesh* the ambient mesh for jit/collectives."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):  # 0.4.x: Mesh is itself a context manager
        return mesh
    return nullcontext()


def abstract_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """Device-free AbstractMesh for spec-building on any jax version."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axis_names)          # jax >= 0.5
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))  # jax 0.4.x
