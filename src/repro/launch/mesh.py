"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Production target: TPU v5e pods.
  single pod: (data=16, model=16)            -- 256 chips
  multi pod:  (pod=2, data=16, model=16)     -- 512 chips
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the same step code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_mediator_mesh(num_devices: int | None = None):
    """1-D mesh over a ``mediator`` axis for the FL round engine.

    Astraea's mediator fleet is embarrassingly parallel across the round
    (mediators only talk at aggregation), so the engine shards the mediator
    batch axis over every available device. On CPU containers this is a
    1-device mesh and the engine degrades to plain vmap semantics.
    """
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("mediator",))


def make_fl_mesh(*, mediator: int | None = None, model: int = 1):
    """2-D ``(mediator, model)`` mesh for the FL round engine.

    The ``mediator`` axis carries the embarrassingly-parallel mediator
    fleet (as in :func:`make_mediator_mesh`); the ``model`` axis
    tensor-shards each mediator row's parameter residency via the
    logical-axis rule tables (``launch/sharding.py``).  ``model=1`` keeps
    a degenerate size-1 model axis -- materially identical to the 1-D
    mediator mesh (every row replicates its full model).

    ``mediator=None`` spreads the remaining devices over the mediator
    axis; the device count must then be divisible by ``model``.
    """
    model = int(model)
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got {model}")
    if mediator is None:
        n = len(jax.devices())
        if n % model:
            raise ValueError(f"{n} devices are not divisible by a "
                             f"model axis of {model}")
        mediator = n // model
    return jax.make_mesh((int(mediator), model), ("mediator", "model"))


def default_fl_mesh(model_parallel: int | None = None):
    """The engine's default mesh: 1-D mediator unless model parallelism is
    requested (argument, else the ``ASTRAEA_MODEL_PARALLEL`` env knob --
    the CI 2x2 leg forces the whole FL suite onto the 2-D mesh with it).

    ``model_parallel <= 1`` returns the plain 1-D ``mediator`` mesh, so
    existing single-axis deployments keep byte-identical programs.
    """
    mp = model_parallel
    if mp is None:
        mp = int(os.environ.get("ASTRAEA_MODEL_PARALLEL", "1") or "1")
    if mp <= 1:
        return make_mediator_mesh()
    return make_fl_mesh(model=mp)


def resolve_fl_mesh(mesh, model_parallel: int | None):
    """Trainer-side mesh resolution (shared by AstraeaTrainer and
    FedAvgTrainer): an explicit mesh always wins; otherwise a
    ``model_parallel`` knob builds the default FL mesh; otherwise ``None``
    so the engine applies its own (env-driven) default."""
    if mesh is not None or model_parallel is None:
        return mesh
    return default_fl_mesh(model_parallel)


def model_axis_size(mesh) -> int:
    """Size of the tensor-parallel ``model`` axis (1 on a 1-D mesh)."""
    return int(dict(mesh.shape).get("model", 1))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def ring_permutation(n: int, step: int) -> list[tuple[int, int]]:
    """The step-``s`` rotation over an ``n``-device axis, as the
    ``(source, dest)`` pairs ``jax.lax.ppermute`` wants.

    The ragged client-store exchange decomposes its all-to-all into the
    ``n - 1`` nonzero rotations of the mediator axis: at hop ``s`` shard
    ``o`` ships its (owner ``o`` -> reader ``(o + s) % n``) slice list.
    Every hop is a full permutation (each device sends and receives
    exactly once), which is what keeps the per-hop buffer shapes static.
    """
    if not 0 < step < n:
        raise ValueError(f"ring step must be in (0, {n}), got {step}")
    return [(o, (o + step) % n) for o in range(n)]


def replicated_sharding(mesh):
    """Every device holds the full array (params, small plan tensors)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def mediator_sharding(mesh):
    """Leading axis split over the ``mediator`` mesh axis.

    Used both for per-mediator round tensors (schedules, keys) and for the
    *client* axis of a ``sharded`` ClientStore: clients are partitioned into
    contiguous blocks of ``K_pad // n`` rows, so device ``d`` owns clients
    ``[d * K_local, (d + 1) * K_local)`` (the owner map the store's
    schedule-time remapping relies on). On a 2-D ``(mediator, model)`` mesh
    the spec leaves the ``model`` axis unmentioned, so client data is
    partitioned over the mediator submesh rows and replicated across each
    row's model columns -- the client axis never shards over ``model``.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec("mediator"))
