"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Production target: TPU v5e pods.
  single pod: (data=16, model=16)            -- 256 chips
  multi pod:  (pod=2, data=16, model=16)     -- 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the same step code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_mediator_mesh(num_devices: int | None = None):
    """1-D mesh over a ``mediator`` axis for the FL round engine.

    Astraea's mediator fleet is embarrassingly parallel across the round
    (mediators only talk at aggregation), so the engine shards the mediator
    batch axis over every available device. On CPU containers this is a
    1-device mesh and the engine degrades to plain vmap semantics.
    """
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("mediator",))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def replicated_sharding(mesh):
    """Every device holds the full array (params, small plan tensors)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def mediator_sharding(mesh):
    """Leading axis split over the ``mediator`` mesh axis.

    Used both for per-mediator round tensors (schedules, keys) and for the
    *client* axis of a ``sharded`` ClientStore: clients are partitioned into
    contiguous blocks of ``K_pad // n`` rows, so device ``d`` owns clients
    ``[d * K_local, (d + 1) * K_local)`` (the owner map the store's
    schedule-time remapping relies on).
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec("mediator"))
