"""Production mesh construction and the multi-process runtime.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Production target: TPU v5e pods.
  single pod: (data=16, model=16)            -- 256 chips
  multi pod:  (pod=2, data=16, model=16)     -- 512 chips

Multi-process execution (``jax.distributed``): ``init_distributed``
joins the coordination service, ``process_local_mesh`` builds a mesh
over this process's own devices only, and ``ProcessWaveDispatcher``
shards async waves across processes, exchanging the wave payloads
host-side through the coordination-service KV store. The process-local
mesh is deliberate: cross-process XLA collectives are not implemented on
the CPU backend, so each process keeps its collectives in-process and
the wave results -- small (M, ...) stacks, not per-step activations --
ride the KV store. On a real TPU multi-host deployment the same
dispatcher composes with global meshes; the CPU smoke leg
(benchmarks/distributed_smoke.py) proves the protocol.
"""
from __future__ import annotations

import io
import os

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the same step code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_mediator_mesh(num_devices: int | None = None):
    """1-D mesh over a ``mediator`` axis for the FL round engine.

    Astraea's mediator fleet is embarrassingly parallel across the round
    (mediators only talk at aggregation), so the engine shards the mediator
    batch axis over every available device. On CPU containers this is a
    1-device mesh and the engine degrades to plain vmap semantics.
    """
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("mediator",))


def make_fl_mesh(*, mediator: int | None = None, model: int = 1):
    """2-D ``(mediator, model)`` mesh for the FL round engine.

    The ``mediator`` axis carries the embarrassingly-parallel mediator
    fleet (as in :func:`make_mediator_mesh`); the ``model`` axis
    tensor-shards each mediator row's parameter residency via the
    logical-axis rule tables (``launch/sharding.py``).  ``model=1`` keeps
    a degenerate size-1 model axis -- materially identical to the 1-D
    mediator mesh (every row replicates its full model).

    ``mediator=None`` spreads the remaining devices over the mediator
    axis; the device count must then be divisible by ``model``.
    """
    model = int(model)
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got {model}")
    if mediator is None:
        n = len(jax.devices())
        if n % model:
            raise ValueError(f"{n} devices are not divisible by a "
                             f"model axis of {model}")
        mediator = n // model
    return jax.make_mesh((int(mediator), model), ("mediator", "model"))


def default_fl_mesh(model_parallel: int | None = None):
    """The engine's default mesh: 1-D mediator unless model parallelism is
    requested (argument, else the ``ASTRAEA_MODEL_PARALLEL`` env knob --
    the CI 2x2 leg forces the whole FL suite onto the 2-D mesh with it).

    ``model_parallel <= 1`` returns the plain 1-D ``mediator`` mesh, so
    existing single-axis deployments keep byte-identical programs.
    """
    mp = model_parallel
    if mp is None:
        mp = int(os.environ.get("ASTRAEA_MODEL_PARALLEL", "1") or "1")
    if mp <= 1:
        return make_mediator_mesh()
    return make_fl_mesh(model=mp)


def resolve_fl_mesh(mesh, model_parallel: int | None):
    """Trainer-side mesh resolution (shared by AstraeaTrainer and
    FedAvgTrainer): an explicit mesh always wins; otherwise a
    ``model_parallel`` knob builds the default FL mesh; otherwise ``None``
    so the engine applies its own (env-driven) default."""
    if mesh is not None or model_parallel is None:
        return mesh
    return default_fl_mesh(model_parallel)


def model_axis_size(mesh) -> int:
    """Size of the tensor-parallel ``model`` axis (1 on a 1-D mesh)."""
    return int(dict(mesh.shape).get("model", 1))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def ring_permutation(n: int, step: int) -> list[tuple[int, int]]:
    """The step-``s`` rotation over an ``n``-device axis, as the
    ``(source, dest)`` pairs ``jax.lax.ppermute`` wants.

    The ragged client-store exchange decomposes its all-to-all into the
    ``n - 1`` nonzero rotations of the mediator axis: at hop ``s`` shard
    ``o`` ships its (owner ``o`` -> reader ``(o + s) % n``) slice list.
    Every hop is a full permutation (each device sends and receives
    exactly once), which is what keeps the per-hop buffer shapes static.
    """
    if not 0 < step < n:
        raise ValueError(f"ring step must be in (0, {n}), got {step}")
    return [(o, (o + step) % n) for o in range(n)]


def replicated_sharding(mesh):
    """Every device holds the full array (params, small plan tensors)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def init_distributed(coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Join (or skip) the ``jax.distributed`` coordination service.

    Arguments fall back to the standard ``JAX_COORDINATOR_ADDRESS`` /
    ``JAX_NUM_PROCESSES`` / ``JAX_PROCESS_ID`` env knobs. A single-process
    configuration (no coordinator, or ``num_processes <= 1``) is a no-op
    returning ``False``; repeated initialization is also a no-op, so
    trainers can call this unconditionally.
    """
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if num_processes is None:
        num_processes = int(os.environ.get("JAX_NUM_PROCESSES", "1") or "1")
    if process_id is None:
        process_id = int(os.environ.get("JAX_PROCESS_ID", "0") or "0")
    if not coordinator or num_processes <= 1:
        return False
    if coordination_client() is not None:      # already joined
        return True
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def coordination_client():
    """The live coordination-service client, or ``None`` when this process
    runs undistributed. The client is the host-side KV store + barrier the
    wave dispatcher exchanges payloads through -- it works across
    processes on every backend, including CPU where cross-process XLA
    collectives do not."""
    from jax._src import distributed as _dist
    return _dist.global_state.client


def process_local_mesh(model: int = 1):
    """Per-process ``(mediator, model)``/1-D mesh over *local* devices.

    Under ``jax.distributed`` each process sees the global device set, but
    programs placed on remote devices need cross-process collectives the
    CPU backend lacks. The async wave dispatcher therefore gives every
    process its own mesh over ``jax.local_devices()`` -- wave executables
    run entirely in-process and results cross process boundaries
    host-side (``ProcessWaveDispatcher``). Shape semantics match
    :func:`make_fl_mesh` restricted to local devices.
    """
    from jax.sharding import Mesh
    model = int(model)
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got {model}")
    local = jax.local_devices()
    if model == 1:
        return Mesh(np.asarray(local).reshape(len(local)), ("mediator",))
    if len(local) % model:
        raise ValueError(f"{len(local)} local devices are not divisible "
                         f"by a model axis of {model}")
    return Mesh(np.asarray(local).reshape(len(local) // model, model),
                ("mediator", "model"))


class ProcessWaveDispatcher:
    """Round-robin wave ownership + host-side payload exchange.

    The async engine asks :meth:`owner_of` which process executes wave
    ``w`` of round ``r``; the owner runs it on its process-local mesh and
    :meth:`publish`-es the resulting arrays through the coordination
    KV store, every other process :meth:`receive`-s them. Ownership is a
    pure function of ``(round, wave)``, so no coordination is needed to
    agree on it, and every process books identical comm charges -- the
    WAN ledger stays process-count-invariant by construction
    (benchmarks/distributed_smoke.py asserts it).

    Payloads are ``np.savez``-framed (ordered, dtype/shape-preserving,
    no pickling); keys are namespaced per round/wave and never reused, so
    late readers always see exactly the bytes the owner wrote.
    """

    def __init__(self, client=None, *, process_index: int | None = None,
                 num_processes: int | None = None,
                 timeout_ms: int = 120_000):
        self.client = client if client is not None else coordination_client()
        if self.client is None:
            raise ValueError("ProcessWaveDispatcher needs a live "
                             "jax.distributed coordination client "
                             "(call init_distributed first)")
        self.process_index = jax.process_index() \
            if process_index is None else int(process_index)
        self.num_processes = jax.process_count() \
            if num_processes is None else int(num_processes)
        if self.num_processes < 1:
            raise ValueError("num_processes must be >= 1")
        self.timeout_ms = int(timeout_ms)
        self.num_published = 0
        self.num_received = 0

    def owner_of(self, round_idx: int, wave_idx: int) -> int:
        """Rotating round-robin: waves of one round spread across
        processes, and the offset rotates per round so short rounds do
        not starve the high-index processes."""
        return (int(round_idx) + int(wave_idx)) % self.num_processes

    def publish(self, tag: str, arrays) -> None:
        buf = io.BytesIO()
        np.savez(buf, *[np.asarray(a) for a in arrays])
        self.client.key_value_set_bytes(f"astraea/{tag}", buf.getvalue())
        self.num_published += 1

    def receive(self, tag: str) -> list[np.ndarray]:
        raw = self.client.blocking_key_value_get_bytes(
            f"astraea/{tag}", self.timeout_ms)
        with np.load(io.BytesIO(raw), allow_pickle=False) as z:
            out = [z[f"arr_{i}"] for i in range(len(z.files))]
        self.num_received += 1
        return out

    def barrier(self, name: str) -> None:
        self.client.wait_at_barrier(f"astraea/{name}", self.timeout_ms)


def mediator_sharding(mesh):
    """Leading axis split over the ``mediator`` mesh axis.

    Used both for per-mediator round tensors (schedules, keys) and for the
    *client* axis of a ``sharded`` ClientStore: clients are partitioned into
    contiguous blocks of ``K_pad // n`` rows, so device ``d`` owns clients
    ``[d * K_local, (d + 1) * K_local)`` (the owner map the store's
    schedule-time remapping relies on). On a 2-D ``(mediator, model)`` mesh
    the spec leaves the ``model`` axis unmentioned, so client data is
    partitioned over the mediator submesh rows and replicated across each
    row's model columns -- the client axis never shards over ``model``.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec("mediator"))
