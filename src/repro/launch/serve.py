"""Serving driver: batched prefill + decode with the KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import InputShape
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = C.reduced(C.get(args.arch))
    max_len = args.prompt_len + args.tokens
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=max_len)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompts.astype(jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.ones(
            (args.batch, cfg.vision_tokens, cfg.d_model), cfg.np_dtype()) * 0.01
    enc_out = None
    if cfg.arch_type == "audio":
        batch["enc_feats"] = jnp.ones(
            (args.batch, cfg.source_positions, cfg.d_model), cfg.np_dtype()) * 0.01
        enc_out = batch["enc_feats"]

    # prefill populates the cache, padded to the decode budget
    def prefill(params, batch):
        logits, cache = T.forward_prefill(params, cfg, batch, pad_to=max_len)
        return jnp.argmax(logits, -1), cache

    t0 = time.time()
    tok, cache = jax.jit(prefill)(params, batch)
    print(f"prefill done in {time.time()-t0:.1f}s; decoding {args.tokens} tokens")

    serve = jax.jit(make_serve_step(cfg))
    out_tokens = [int(t) for t in np.asarray(tok[:, 0])]
    cur = tok.astype(jnp.int32)
    t0 = time.time()
    for i in range(args.tokens - 1):
        b = {"tokens": cur, "positions": jnp.full((args.batch,),
                                                  args.prompt_len + i, jnp.int32)}
        if enc_out is not None:
            b["enc_out"] = enc_out
        cur, cache = serve(params, b, cache)
        cur = cur.astype(jnp.int32)
    dt = (time.time() - t0) / max(args.tokens - 1, 1)
    print(f"decode: {dt*1e3:.1f} ms/token/batch; sample row: "
          f"{out_tokens[:1] + [int(cur[0,0])]}")


if __name__ == "__main__":
    main()
