"""CPU-runnable training driver for any assigned architecture (reduced or
full config -- full configs only make sense under the dry-run, so the
default is the reduced smoke variant).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import InputShape, make_batch
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import adamw, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full config (requires the dry-run mesh)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = C.get(args.arch)
    if not args.full_config:
        cfg = C.reduced(cfg)
    shape = InputShape("cli", args.seq, args.batch, "train")

    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=args.seq)
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M seq={args.seq} batch={args.batch}")

    opt = adamw(warmup_cosine(args.lr, 10, max(args.steps, 20)))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for step in range(args.steps):
        key = jax.random.fold_in(key, step)
        toks = jax.random.randint(key, (args.batch, args.seq), 0, cfg.vocab)
        batch = make_batch(cfg, shape)["batch"]
        batch["tokens"] = toks.astype(jnp.int32)
        batch["labels"] = jnp.roll(toks, -1, axis=1).astype(jnp.int32)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):8.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
        assert np.isfinite(float(loss)), "training diverged"

    if args.ckpt:
        from repro.checkpoint import save_pytree
        save_pytree(args.ckpt, {"params": params, "step": args.steps})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
