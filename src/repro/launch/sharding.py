"""Logical-axis -> mesh-axis sharding rules (MaxText-style, with fallbacks).

Every parameter in the model zoo carries logical axis names (see
models.layers.LogicalParam). A *rule table* maps each logical axis to an
ordered list of candidate mesh axes; the first candidate whose size divides
the dimension and is not already used by the same parameter wins, otherwise
the dimension is replicated. This gives correct-by-construction
PartitionSpecs for every architecture (e.g. internvl2's 14 heads simply
fall back to replicated attention weights while its MLP/vocab still shard).

Two standard rule sets:
  TRAIN_RULES     -- FSDP x TP: "embed" shards over data, wide dims over model.
  INFER_RULES     -- same (big checkpoints need weight sharding at inference
                     too); decode caches shard batch over data.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L

PyTree = Any

TRAIN_RULES: dict[str, list] = {
    "vocab": ["model"],
    "embed": [("pod", "data"), "data"],   # FSDP/ZeRO-3 style weight sharding
    "heads": ["model"],
    "kv_heads": ["model"],
    "head_dim": [],
    "mlp": ["model"],
    "expert": ["model"],
    "layers": [],
    "ssm_proj": ["model"],
    "ssm_conv": ["model"],
    "ssm_inner": ["model"],
    "ssm_heads": ["model"],
    "conv": [],
    "pos": [],
}

INFER_RULES = dict(TRAIN_RULES)


def model_only_rules(rules: dict[str, list] | None = None) -> dict[str, list]:
    """Strip every candidate except ``"model"`` from a rule table.

    Federated replicas diverge during a round, so parameters must never
    shard over the mediator/data axes -- the FL round engine and the
    dry-run's ``make_fl_round`` lowering both shard weights over the
    tensor-parallel ``model`` axis only.
    """
    rules = rules or TRAIN_RULES
    return {k: [a for a in v if a == "model"] for k, v in rules.items()}


def spec_for(shape: tuple[int, ...], axes: tuple[str, ...], mesh: Mesh,
             rules: dict[str, list[str]]) -> P:
    """PartitionSpec for one parameter under the rule table."""
    used: set[str] = set()
    parts = []
    for dim, logical in zip(shape, axes):
        chosen = None
        for cand in rules.get(logical, []):
            cand_t = cand if isinstance(cand, tuple) else (cand,)
            if any(a not in mesh.axis_names or a in used for a in cand_t):
                continue
            size = 1
            for a in cand_t:
                size *= mesh.shape[a]
            if dim % size == 0 and dim >= size:
                chosen = cand_t if len(cand_t) > 1 else cand_t[0]
                used.update(cand_t)
                break
        parts.append(chosen)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(specs: PyTree, mesh: Mesh,
                    rules: dict[str, list[str]] | None = None) -> PyTree:
    """NamedSharding pytree for a LogicalParam spec pytree."""
    rules = rules or TRAIN_RULES

    def leaf(sp: L.LogicalParam):
        return NamedSharding(mesh, spec_for(sp.shape, sp.axes, mesh, rules))

    return jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, L.LogicalParam))


def adapter_shardings(mapping: dict, mesh: Mesh,
                      rules: dict[str, list] | None = None
                      ) -> tuple[PyTree, PyTree]:
    """NamedSharding trees ``(state, A)`` for a LoRA adapter mapping table
    (``models/lora.py``).

    Dense entries mirror their backbone tensor's rule-table spec exactly
    (they ARE the effective tensor).  Factorized entries keep the batch
    axes' rules, put the backbone's last logical axis on ``dout`` of ``B``
    and fold-in axes on ``din`` of ``A``, and tag the rank dim
    ``"lora_rank"`` -- absent from every standard table, so rank is
    replicated (it is tiny and both factors contract over it)."""
    rules = rules or TRAIN_RULES
    state, a = {}, {}
    for path, e in mapping.items():
        if e.kind == "dense":
            state[path] = NamedSharding(
                mesh, spec_for(e.shape, e.axes, mesh, rules))
            continue
        state[path] = NamedSharding(mesh, spec_for(
            e.state_shape, e.batch_axes + ("lora_rank", e.axes[-1]),
            mesh, rules))
        a[path] = NamedSharding(mesh, spec_for(
            e.a_shape, e.batch_axes + ("lora_din", "lora_rank"),
            mesh, rules))
    return state, a


def batch_shardings(batch_specs: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (batch) dim of every input over the data axes."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))

    def leaf(sd):
        if sd.shape and sd.shape[0] % dsize == 0 and sd.shape[0] >= dsize:
            return NamedSharding(mesh, P(daxes))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf, batch_specs)


def cache_shardings(cache_specs: PyTree, mesh: Mesh) -> PyTree:
    """Decode caches: (layers, batch, ...) -- shard batch (axis 1) over data,
    and the head/state axis over model when divisible."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    msize = mesh.shape.get("model", 1)

    def leaf(sd):
        parts: list = [None] * len(sd.shape)
        if len(sd.shape) >= 2 and sd.shape[1] % dsize == 0 and sd.shape[1] >= dsize:
            parts[1] = daxes
        # kv-head axis of attention caches: (L, b, S, KV, hd) -> axis 3;
        # ssm state (L, b, h, p, n) -> heads at axis 2
        for ax in (3, 2):
            if len(sd.shape) > ax + 1 and parts[ax] is None \
                    and sd.shape[ax] % msize == 0 and sd.shape[ax] >= msize:
                parts[ax] = "model"
                break
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(leaf, cache_specs)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def opt_state_shardings(opt_init, param_sds: PyTree, param_shapes: PyTree,
                        mesh: Mesh) -> PyTree:
    """Shardings for optimizer state: moments mirror their parameters."""
    state_shape = jax.eval_shape(opt_init, param_shapes)

    def build(tree):
        # {"step": scalar, "adam"/"sgd": NamedTuple of param-shaped trees}
        out = {}
        for k, v in tree.items():
            if k == "step":
                out[k] = replicated(mesh)
            else:
                out[k] = type(v)(*[param_sds if leafs is not None else None
                                   for leafs in v])
        return out

    return build(state_shape)
