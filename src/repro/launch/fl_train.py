"""Astraea federated training of a transformer on the mesh (paper technique
as a first-class framework feature, beyond the CNN simulator).

Each ("pod","data") slice acts as one mediator; Alg. 3 decides which
clients' token streams land on which slice; the sync round is ONE XLA
program (see launch.steps.make_fl_round). On CPU this runs the same code
on a 1x1 host mesh.

  PYTHONPATH=src python -m repro.launch.fl_train --arch qwen3-4b --rounds 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import scheduling
from repro.core.comm import CommMeter
from repro.launch.compat import use_mesh
from repro.launch.mesh import init_distributed, make_host_mesh
from repro.launch.sharding import param_shardings, TRAIN_RULES
from repro.launch.steps import make_fl_round
from repro.models import layers as L
from repro.models import lora as lora_lib
from repro.models import transformer as T


def synth_client_streams(key, n_clients: int, vocab: int, seq: int,
                         n_topics: int = 8):
    """Synthetic non-IID clients: each client's tokens cluster in a topic
    band of the vocab (label distribution == topic histogram)."""
    streams, counts = [], []
    for i in range(n_clients):
        k = jax.random.fold_in(key, i)
        topic = int(jax.random.randint(k, (), 0, n_topics))
        lo = topic * (vocab // n_topics)
        hi = lo + vocab // n_topics
        toks = jax.random.randint(jax.random.fold_in(k, 1), (seq,), lo, hi)
        streams.append(toks.astype(jnp.int32))
        hist = np.zeros(n_topics)
        hist[topic] = seq
        counts.append(hist)
    return streams, np.asarray(counts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-4)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size of the (data, model) mesh: each "
                         "mediator slice tensor-shards its replica over "
                         "this many devices (device count must divide)")
    ap.add_argument("--lora-rank", type=int, default=None,
                    help="LoRA adapter rank: freeze the backbone and ship "
                         "ONLY the per-tensor adapter state over the WAN "
                         "(models/lora.py mapping table); 0 freezes "
                         "everything, unset = full-delta exchange")
    ap.add_argument("--lora-alpha", type=float, default=None,
                    help="LoRA merge scale alpha (default: rank, i.e. 1.0)")
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator address "
                         "(host:port); enables the multi-process runtime "
                         "-- each process trains on a process-local mesh "
                         "and the WAN ledger stays process-count-"
                         "invariant (env: JAX_COORDINATOR_ADDRESS)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total jax.distributed processes "
                         "(env: JAX_NUM_PROCESSES)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank (env: JAX_PROCESS_ID)")
    args = ap.parse_args()

    distributed = init_distributed(args.coordinator, args.num_processes,
                                   args.process_id)
    if distributed:
        print(f"distributed: process {jax.process_index()}/"
              f"{jax.process_count()} with {len(jax.local_devices())} "
              f"local device(s)")

    cfg = C.reduced(C.get(args.arch))
    if args.model_parallel > 1:
        # under jax.distributed, programs stay on this process's own
        # devices (cross-process XLA collectives are unavailable on CPU)
        devs = jax.local_devices() if distributed else jax.devices()
        nd = len(devs)
        if nd % args.model_parallel:
            raise SystemExit(f"{nd} devices not divisible by "
                             f"--model-parallel {args.model_parallel}")
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(devs).reshape(nd // args.model_parallel,
                                             args.model_parallel),
                    ("data", "model"))
    elif distributed:
        # 1x1 over a LOCAL device: jax.make_mesh would grab the global
        # device list, whose head lives on process 0 for everyone else
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.local_devices()[:1]).reshape(1, 1),
                    ("data", "model"))
    else:
        mesh = make_host_mesh()
    n_mediators = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                               if a in ("pod", "data")]))

    specs = T.param_specs(cfg, max_seq=args.seq)
    p_shards = param_shardings(specs, mesh, TRAIN_RULES)
    spec_tree = jax.tree.map(lambda ns: ns.spec, p_shards)
    params = T.init_params(jax.random.PRNGKey(0), cfg, max_seq=args.seq)

    # WAN ledger: the paper's traffic claim, measured instead of assumed
    meter = CommMeter(T.param_count(cfg, max_seq=args.seq),
                      bytes_per_param=np.dtype(cfg.np_dtype()).itemsize)
    mapping = None
    a_tree = state = None
    if args.lora_rank is not None:
        mapping = T.adapter_mapping(cfg, args.lora_rank, args.lora_alpha,
                                    max_seq=args.seq)
        a_key = jax.random.fold_in(jax.random.PRNGKey(0), lora_lib.A_SALT)
        a_tree = lora_lib.init_adapter_A(a_key, mapping)
        state = lora_lib.init_adapter_state(mapping, params)
        meter.adapter_payload_bytes = lora_lib.exchange_nbytes(
            mapping, meter.bytes_per_param)
        print(f"lora rank={args.lora_rank}: "
              f"{lora_lib.num_trainable_params(mapping)} trainable params, "
              f"{meter.adapter_payload_bytes} bytes/leg "
              f"(full leg {int(meter.model_bytes)})")

    streams, counts = synth_client_streams(jax.random.PRNGKey(1), args.clients,
                                           cfg.vocab, args.seq)
    # Alg. 3: schedule clients onto mediators by KLD-to-uniform of topics
    meds = scheduling.reschedule(counts, gamma=args.gamma)
    stats = scheduling.schedule_stats(meds)
    print(f"mediators={stats['num_mediators']} kld_mean={stats['kld_mean']:.3f}")

    # pack: each mediator's clients concatenated client-major (sequential)
    per_med = max(len(m.clients) for m in meds)
    rows = []
    weights = []
    for m in meds[:n_mediators]:
        toks = jnp.concatenate([streams[c] for c in m.clients] +
                               [jnp.zeros(((per_med - len(m.clients)) * args.seq,),
                                          jnp.int32)])
        rows.append(toks.reshape(per_med, args.seq))
        weights.append(float(sum(counts[c].sum() for c in m.clients)))
    # (n_mediators * per_med, seq) -- slice b of the data axis = mediator b
    tokens = jnp.concatenate(rows)[: n_mediators * per_med]
    labels = jnp.roll(tokens, -1, axis=1)
    w = jnp.asarray(np.repeat(weights[:n_mediators], per_med), jnp.float32)

    fl_round = make_fl_round(cfg, mesh, spec_tree, learning_rate=args.lr,
                             local_steps=per_med, mediator_epochs=1,
                             lora_mapping=mapping)
    L.set_activation_mesh(None)
    fl_jit = jax.jit(fl_round)

    n_clients_sched = sum(len(m.clients) for m in meds[:n_mediators])
    for r in range(args.rounds):
        t0 = time.time()
        with use_mesh(mesh):
            if mapping is not None:
                state = fl_jit(params, a_tree, state, tokens, labels, w)
                eval_params = lora_lib.merge_params(params, a_tree, state,
                                                    mapping)
            else:
                params = fl_jit(params, tokens, labels, w)
                eval_params = params
        # each round: model/adapter down+up per client plus the
        # server<->mediator legs (the Astraea WAN formula)
        wan0 = meter.total_bytes
        meter.astraea_round(n_clients_sched, args.gamma)
        meter.end_round()
        loss, _ = T.forward_train(eval_params, cfg,
                                  {"tokens": tokens[:2], "labels": labels[:2]})
        print(f"round {r}: loss={float(loss):.4f} "
              f"wan={meter.total_bytes - wan0:.0f}B "
              f"({time.time()-t0:.1f}s)")
        assert np.isfinite(float(loss))

    # the measured per-round WAN ledger (not the back-of-envelope claim)
    print("WAN ledger:")
    for key, total in meter.ledger_totals().items():
        print(f"  {key}: {total:.0f}")
    ratio = meter.adapter_reduction_ratio
    if ratio is not None:
        print(f"  adapter/full byte ratio: {ratio:.4f} "
              f"({(1 - ratio) * 100:.1f}% WAN reduction)")
    print("done")


if __name__ == "__main__":
    main()
