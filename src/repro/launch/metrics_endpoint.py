"""Stdlib-HTTP ``/metrics`` endpoint for scrape-based deployments.

``MetricsServer`` wraps a ``MetricsRegistry`` (or any zero-arg callable
returning Prometheus text) in a ``ThreadingHTTPServer`` on a daemon
thread: ``GET /metrics`` renders the registry at scrape time, so a
long-running training loop is observable without touching the round path
-- the handler only ever *reads* registry state that the host-side
telemetry hooks already wrote.

No third-party dependency: the exposition format is produced by
``repro.obs.metrics.MetricsRegistry.to_prometheus`` and served with the
conventional ``text/plain; version=0.0.4`` content type.

CLI mode serves a previously flushed ``metrics.prom`` artifact from a
``--trace-dir`` (post-hoc scraping of a finished run)::

    python -m repro.launch.metrics_endpoint --trace-dir /tmp/trace --port 9100
"""
from __future__ import annotations

import argparse
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _make_handler(render: Callable[[], str]):
    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404, "only /metrics is served")
                return
            try:
                body = render().encode()
            except Exception as exc:      # surface render bugs to the scraper
                self.send_error(500, f"metrics render failed: {exc}")
                return
            self.send_response(200)
            self.send_header("Content-Type", CONTENT_TYPE)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):     # keep scrapes out of stdout
            pass

    return Handler


class MetricsServer:
    """Daemon-thread ``/metrics`` server around a registry or callable.

    ``port=0`` binds an ephemeral port (tests); read it back from
    ``server.port`` / ``server.url`` after ``start()``.
    """

    def __init__(self, registry, host: str = "127.0.0.1", port: int = 0):
        self._render = (registry if callable(registry)
                        else registry.to_prometheus)
        self.host, self.port = host, port
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    def start(self) -> "MetricsServer":
        self._httpd = ThreadingHTTPServer((self.host, self.port),
                                          _make_handler(self._render))
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-endpoint", daemon=True)
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace-dir", required=True,
                    help="directory holding a flushed metrics.prom")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=9100)
    args = ap.parse_args(argv)
    prom = os.path.join(args.trace_dir, "metrics.prom")

    def render() -> str:
        with open(prom) as f:
            return f.read()

    server = MetricsServer(render, host=args.host, port=args.port).start()
    print(f"serving {prom} at {server.url}")
    try:
        server._thread.join()
    except KeyboardInterrupt:
        server.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
