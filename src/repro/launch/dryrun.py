import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices. Only
this entry point sets the flag -- tests and benches see 1 CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # single-pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2x16x16
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import INPUT_SHAPES, input_specs
from repro.launch import sharding as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_train_step, make_prefill_step,
                                make_serve_step, suggest_microbatches)
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import set_activation_mesh, set_param_cot_specs
from repro.optim import adam
from repro.roofline import parse_hlo_costs, roofline_from_costs, model_flops

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "single16x16"


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention architecture without a sliding-window/SSM "
                "variant: 524k dense decode is intentionally N/A (DESIGN.md)")
    return None


def build_fl_lowerable(cfg, shape, mesh):
    """Astraea synchronization round (make_fl_round) on the mesh: the
    paper's technique as ONE XLA program. Params are model-sharded only
    (each mediator slice holds a replica); batch rows are mediator client
    streams. Lowered for train_4k-style shapes."""
    from repro.launch.steps import make_fl_round
    import dataclasses as _dc
    # jax.checkpoint inside a partial-auto shard_map trips an XLA
    # "Invalid binary instruction opcode copy" crash (b/433785288-adjacent);
    # the FL round scans microbatches anyway, so disable remat here.
    cfg = _dc.replace(cfg, remat=False)
    specs = T.param_specs(cfg, max_seq=shape.seq_len)
    p_structs = L.shape_dtype(specs)
    # model-sharded only: strip data axes from the train rules
    p_shards = S.param_shardings(specs, mesh, S.model_only_rules())
    spec_tree = jax.tree.map(lambda ns: ns.spec, p_shards)
    B, Ssz = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    args = (p_structs,
            jax.ShapeDtypeStruct((B, Ssz), i32),
            jax.ShapeDtypeStruct((B, Ssz), i32),
            jax.ShapeDtypeStruct((B,), jnp.float32))
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    bsh = NamedSharding(mesh, P(daxes))
    wsh = NamedSharding(mesh, P(daxes))
    dp = int(np.prod([mesh.shape[a] for a in daxes]))
    fn = make_fl_round(cfg, mesh, spec_tree, local_steps=max(B // dp, 1),
                       mediator_epochs=1)
    return (fn, args, (p_shards, bsh, bsh, wsh), p_shards, (0,))


def build_lowerable(cfg, shape, mesh):
    """Returns (fn, args, in_shardings, out_shardings, donate)."""
    specs = param_specs = T.param_specs(cfg, max_seq=max(shape.seq_len, 4096))
    p_structs = L.shape_dtype(specs)
    p_shards = S.param_shardings(specs, mesh, S.TRAIN_RULES)
    ins = input_specs(cfg, shape)
    b_shards = S.batch_shardings(ins["batch"], mesh)

    if shape.kind == "train":
        moment_dtype = jnp.bfloat16 if T.param_count(cfg) > 10e9 else None
        opt = adam(1e-4, moment_dtype=moment_dtype)
        o_structs = jax.eval_shape(opt.init, p_structs)
        o_shards = S.opt_state_shardings(opt.init, p_shards, p_structs, mesh)
        mb = suggest_microbatches(cfg, shape.global_batch, shape.seq_len, mesh)
        fn = make_train_step(cfg, opt, microbatches=mb, grad_shardings=p_shards)
        return (fn, (p_structs, o_structs, ins["batch"]),
                (p_shards, o_shards, b_shards),
                (p_shards, o_shards, S.replicated(mesh)), (0, 1))

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        cache_struct = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_shards = S.cache_shardings(cache_struct, mesh)
        tok_shard = S.batch_shardings(
            {"t": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}, mesh)["t"]
        return (fn, (p_structs, ins["batch"]), (p_shards, b_shards),
                (tok_shard, c_shards), ())

    # decode
    fn = make_serve_step(cfg)
    c_shards = S.cache_shardings(ins["cache"], mesh)
    tok_shard = S.batch_shardings(
        {"t": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}, mesh)["t"]
    return (fn, (p_structs, ins["batch"], ins["cache"]),
            (p_shards, b_shards, c_shards), (tok_shard, c_shards), (2,))


def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            save_hlo: bool = False, out_dir: str = OUT_DIR,
            rules=None, tag: str = "", fl_round: bool = False) -> dict:
    cfg = C.get(arch_id)
    shape = INPUT_SHAPES[shape_name]
    mesh_nm = _mesh_name(multi_pod)
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_nm,
                 "kind": "fl_round" if fl_round else shape.kind, "tag": tag,
                 "params_total": T.param_count(cfg),
                 "params_active": T.active_param_count(cfg)}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    if rules is not None:
        orig_rules = S.TRAIN_RULES.copy()
        S.TRAIN_RULES.clear()
        S.TRAIN_RULES.update(rules)
    try:
        from repro.models.layers import ACT_RULES
        orig_moe_tokens = ACT_RULES["moe_tokens"]
        if cfg.moe_token_parallel and rules is None:
            rules = dict(S.TRAIN_RULES)
            rules["mlp"] = []
            ACT_RULES["moe_tokens"] = ("pod", "data", "model")
            orig_rules = S.TRAIN_RULES.copy()
            S.TRAIN_RULES.clear()
            S.TRAIN_RULES.update(rules)
        if fl_round:
            fn, args, in_sh, out_sh, donate = build_fl_lowerable(cfg, shape, mesh)
            set_activation_mesh(None)   # constraints inside shard_map trip XLA
        else:
            fn, args, in_sh, out_sh, donate = build_lowerable(cfg, shape, mesh)
            set_activation_mesh(mesh)
        if shape.kind == "train" and not fl_round:
            from jax.sharding import NamedSharding, PartitionSpec as P
            layer_shards = S.param_shardings(
                T.param_specs(cfg, max_seq=max(shape.seq_len, 4096)), mesh,
                S.TRAIN_RULES)["layers"]
            # drop the leading stacked-layers axis of each spec
            per_layer = jax.tree.map(
                lambda ns: NamedSharding(mesh, P(*tuple(ns.spec)[1:])), layer_shards)
            set_param_cot_specs(per_layer)
        t0 = time.time()
        from repro.launch.compat import use_mesh
        with use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
    finally:
        set_activation_mesh(None)
        set_param_cot_specs(None)
        ACT_RULES["moe_tokens"] = orig_moe_tokens
        if rules is not None:
            S.TRAIN_RULES.clear()
            S.TRAIN_RULES.update(orig_rules)

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    hlo_text = compiled.as_text()
    costs = parse_hlo_costs(hlo_text)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = model_flops(cfg, tokens, "train" if fl_round else shape.kind) / n_chips
    terms = roofline_from_costs(costs.flops, costs.bytes_accessed,
                                costs.collective_bytes, mflops)

    rec.update(
        status="ok", n_chips=n_chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_estimate_gb=round((mem.argument_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    + mem.output_size_in_bytes
                                    - mem.alias_size_in_bytes) / 2**30, 3)),
        xla_cost_analysis=dict(flops=ca.get("flops", 0.0),
                               bytes=ca.get("bytes accessed", 0.0)),
        hlo_costs=dict(flops=costs.flops, bytes=costs.bytes_accessed,
                       collective_bytes=costs.collective_bytes,
                       collective_by_kind=costs.collective_by_kind,
                       while_trips=costs.while_trips),
        roofline=terms.as_dict(),
    )
    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_nm}.hlo"),
                  "w") as f:
            f.write(hlo_text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = C.ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for aid in archs:
        for snm in shapes:
            t0 = time.time()
            try:
                rec = run_one(aid, snm, args.multi_pod, args.save_hlo, args.out)
            except Exception as e:  # a failure here is a sharding bug
                rec = {"arch": aid, "shape": snm, "mesh": _mesh_name(args.multi_pod),
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                failures += 1
            fname = f"{aid}__{snm}__{_mesh_name(args.multi_pod)}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=2, default=float)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" dom={r['dominant']:10s} comp={r['compute_s']*1e3:9.2f}ms"
                         f" mem={r['memory_s']*1e3:9.2f}ms coll={r['collective_s']*1e3:9.2f}ms"
                         f" peak={rec['memory']['peak_estimate_gb']:7.2f}GB"
                         f" compile={rec['compile_s']:6.1f}s")
            elif status == "skipped":
                extra = " (" + rec["skip_reason"][:60] + ")"
            else:
                extra = " " + rec.get("error", "")[:120]
            print(f"[{time.time()-t0:6.1f}s] {aid:24s} {snm:12s} {status:8s}{extra}",
                  flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
