"""Jit-able step functions: train_step / prefill_step / serve_step / fl_round.

These are what the dry-run lowers for every (architecture x input shape x
mesh) and what the CPU-scale drivers execute. The federated round
(``make_fl_round``) is the paper's technique mapped onto the mesh: each
("pod","data") slice is one Astraea *mediator* training its scheduled
clients sequentially from its own replica, with the FedAvg aggregation
(Eq. 6) as a weighted all-reduce of parameter deltas -- manual over the
mediator axes (jax.shard_map), compiler-auto over "model" (tensor
parallelism stays pjit-style inside).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm

PyTree = Any


# --------------------------------------------------------------------------
# Standard training / serving steps (pjit; dry-run targets)
# --------------------------------------------------------------------------

def make_train_step(cfg: T.ArchConfig, opt: Optimizer, *, clip_norm: float = 1.0,
                    microbatches: int = 1, grad_shardings=None,
                    accum_dtype=jnp.float32):
    """fwd+bwd+update. ``microbatches`` > 1 scans gradient accumulation over
    batch slices -- saved activations shrink by the same factor (the knob
    that fits 100B+ training into v5e HBM).

    ``grad_shardings`` (a NamedSharding pytree mirroring params) pins the
    accumulation buffers AND the per-microbatch gradients to the parameter
    sharding -- without it XLA materializes replicated fp32 accumulators
    and all-reduces every microbatch's gradients at full size (§Perf H1:
    the dominant collective in the naive baseline)."""
    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_shardings)

    def grad_of(params, batch):
        def loss_fn(p):
            loss, metrics = T.forward_train(p, cfg, batch)
            return loss
        loss, g = jax.value_and_grad(loss_fn)(params)
        return loss, pin(g)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, b):
                loss_sum, g_sum = carry
                loss, g = grad_of(params, b)
                return (loss_sum + loss,
                        pin(jax.tree.map(jnp.add, g_sum, g))), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: (g / microbatches), grads)
        grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss
    return train_step


def suggest_microbatches(cfg: T.ArchConfig, global_batch: int, seq_len: int,
                         mesh, budget_bytes: float = 4e9) -> int:
    """Napkin: saved residuals/device ~= L * (B/dp/m) * (S/tp) * d * 6 bytes
    (bf16 carry + the f32 convert XLA materializes). Pick the smallest
    power-of-two m that fits ``budget_bytes``."""
    import numpy as _np
    dp = int(_np.prod([mesh.shape[a] for a in mesh.axis_names if a in ("pod", "data")]))
    tp = mesh.shape.get("model", 1)
    seq_shards = tp if seq_len % tp == 0 else 1
    layers = cfg.n_layers + cfg.encoder_layers
    m = 1
    while m < global_batch // dp:
        saved = layers * (global_batch / dp / m) * (seq_len / seq_shards) * cfg.d_model * 6
        if saved <= budget_bytes:
            break
        m *= 2
    return m


def make_prefill_step(cfg: T.ArchConfig):
    def prefill_step(params, batch):
        logits, cache = T.forward_prefill(params, cfg, batch)
        return jnp.argmax(logits, axis=-1), cache
    return prefill_step


def make_serve_step(cfg: T.ArchConfig):
    """One decode step: next-token logits + updated cache."""
    def serve_step(params, batch, cache):
        logits, cache = T.forward_decode(params, cfg, batch, cache)
        return jnp.argmax(logits, axis=-1), cache
    return serve_step


# --------------------------------------------------------------------------
# Astraea federated round on the mesh
# --------------------------------------------------------------------------

def make_fl_round(cfg: T.ArchConfig, mesh, param_spec_tree: PyTree,
                  *, learning_rate: float = 1e-3, local_steps: int = 4,
                  mediator_epochs: int = 1, lora_mapping: dict | None = None):
    """Astraea synchronization round as a single XLA program.

    A thin transformer adapter over the engine's shared round machinery
    (``core.engine.mediator_shard_map`` / ``psum_eq6`` -- the one federated
    round implementation): this function only supplies the per-mediator
    row body (sequential SGD over the client stream).  The mediator axes
    here are the ("pod","data") mesh axes; ``core.engine.FLRoundEngine``
    runs the same helpers over the ``mediator`` axis of an FL mesh.

    Inputs (global view):
      params:  model-sharded ONLY (each mediator slice holds a full replica
               of its model-parallel shard -- mediators diverge during the
               round, so no FSDP over the mediator axes).
      tokens/labels: (B, S) with B = n_mediators * local_batch; slice b of
               the data axes is mediator b's scheduled client data, ordered
               client-major (sequential-client semantics of Alg. 1 ==
               microbatch scan order).
      weights: (B,) per-row token counts n_m (padding rows -> 0).

    The round runs `mediator_epochs` x `local_steps` sequential SGD steps
    per mediator (asynchronous SGD inside the mediator), then aggregates
    deltas with the FedAvg weights via ``psum_eq6`` over the mediator axes
    (the production memory profile: no (M, ...) stack is materialized --
    the engine's replicated-stack ``eq6_aggregate`` would not fit at pod
    scale).

    With ``lora_mapping`` (a ``models/lora.py`` adapter table) the round
    becomes parameter-efficient: the returned callable takes
    ``(backbone, a_tree, state, tokens, labels, weights)``, the backbone
    and the seeded frozen ``A`` bases stay fixed, each mediator trains the
    flat adapter ``state`` dict through the merge inside the loss, and
    Eq. 6 reduces the ADAPTER deltas over the mediator axes -- the only
    thing that ever needs to ride the WAN.
    """
    from repro.core.engine import mediator_shard_map, psum_eq6

    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    # Manual axes are only the mediator ("pod","data") axes; the "model"
    # axis stays compiler-auto, so in_specs must not mention it -- params
    # are replicated across mediators (each holds a full replica of its
    # model-parallel shard) and their model sharding rides along via the
    # auto mechanism.
    pspecs = jax.tree.map(lambda _: P(), param_spec_tree)
    bspec = P(daxes)

    if lora_mapping is not None:
        from repro.models import lora
        s_specs = lora.state_spec_tree(lora_mapping, P())
        a_specs = lora.a_spec_tree(lora_mapping, P())

        def fl_body_lora(backbone, a_tree, state, tokens, labels, weights):
            from repro.models import layers as _L
            _L.set_manual_axes(daxes)
            start = state
            lb = tokens.shape[0]
            micro = lb // local_steps

            def sgd_step(s, mb):
                mt, ml = mb

                def loss_fn(st):
                    merged = lora.merge_params(backbone, a_tree, st,
                                               lora_mapping)
                    loss, _ = T.forward_train(merged, cfg,
                                              {"tokens": mt, "labels": ml})
                    return loss

                g = jax.grad(loss_fn)(s)
                return jax.tree.map(
                    lambda a, b: (a - learning_rate * b).astype(a.dtype),
                    s, g), None

            def epoch(s, _):
                mts = tokens.reshape(local_steps, micro, -1)
                mls = labels.reshape(local_steps, micro, -1)
                s, _ = jax.lax.scan(sgd_step, s, (mts, mls))
                return s, None

            s, _ = jax.lax.scan(epoch, state, None, length=mediator_epochs)
            # adapter-delta Eq. 6 (f32, same rationale as the full path);
            # shared frozen A makes this exactly Eq. 6 on weight deltas
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                s, start)
            avg = psum_eq6(delta, jnp.sum(weights), daxes)
            out = jax.tree.map(lambda p, d: (p + d).astype(p.dtype),
                               start, avg)
            _L.set_manual_axes(())
            return out

        return mediator_shard_map(
            fl_body_lora, mesh,
            in_specs=(pspecs, a_specs, s_specs, bspec, bspec, bspec),
            out_specs=s_specs, mediator_axes=daxes, check=False)

    def fl_body(params, tokens, labels, weights):
        # tokens here: (local_batch, S) -- this mediator's client stream
        from repro.models import layers as _L
        _L.set_manual_axes(daxes)
        start = params
        lb = tokens.shape[0]
        micro = lb // local_steps

        def sgd_step(w, mb):
            mt, ml = mb
            def loss_fn(p):
                loss, _ = T.forward_train(p, cfg, {"tokens": mt, "labels": ml})
                return loss
            g = jax.grad(loss_fn)(w)
            return jax.tree.map(lambda a, b: (a - learning_rate * b).astype(a.dtype),
                                w, g), None

        def epoch(w, _):
            mts = tokens.reshape(local_steps, micro, -1)
            mls = labels.reshape(local_steps, micro, -1)
            w, _ = jax.lax.scan(sgd_step, w, (mts, mls))
            return w, None

        w, _ = jax.lax.scan(epoch, params, None, length=mediator_epochs)
        # Eq. 6 aggregation in f32: numerically safer for the weighted
        # delta average, and works around an XLA-CPU crash ("Invalid
        # binary instruction opcode copy") for bf16 psum under
        # partial-auto shard_map.
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), w, start)
        avg = psum_eq6(delta, jnp.sum(weights), daxes)
        out = jax.tree.map(lambda p, d: (p + d).astype(p.dtype), start, avg)
        _L.set_manual_axes(())
        return out

    return mediator_shard_map(fl_body, mesh,
                              in_specs=(pspecs, bspec, bspec, bspec),
                              out_specs=pspecs, mediator_axes=daxes,
                              check=False)
