"""Three-term roofline model + analytic FLOP cross-check.

Hardware constants (TPU v5e, per the brief):
  peak compute 197 TFLOP/s bf16 per chip; HBM 819 GB/s; ICI ~50 GB/s/link.

Terms (seconds per step, per chip -- HLO numbers are already per-device):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_bw

``model_flops`` is the 6*N*D (dense) / 6*N_active*D (MoE) useful-compute
reference; ``useful_ratio`` = model / compiled catches remat & redundancy.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.transformer import ArchConfig, param_count, active_param_count


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # B/s
    ici_bw: float = 50e9             # B/s/link


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Naive no-overlap bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
        }


def roofline_from_costs(flops: float, bytes_accessed: float,
                        collective_bytes: float, model_flops_total: float,
                        hw: HW = HW()) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=bytes_accessed / hw.hbm_bw,
        collective_s=collective_bytes / hw.ici_bw,
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes,
        model_flops=model_flops_total,
        useful_ratio=model_flops_total / max(flops, 1.0),
    )


def kernel_roofline(flops: float, bytes_accessed: float,
                    hw: HW = HW()) -> dict:
    """Two-term (compute/HBM) roofline bound for a single kernel launch.

    Takes the kernel's ANALYTIC cost (the same flops/bytes the Pallas
    ``CostEstimate`` advertises to the compiler -- ``kernels.fedavg_agg.
    cost_estimate``, ``kernels.kld_score.score_cost`` / ``greedy_cost``)
    and returns the no-overlap lower bound on wall time plus which wall
    the kernel sits against. ``intensity`` vs ``ridge_intensity``
    (peak_flops / hbm_bw, FLOP/byte) says how far from the ridge point.
    """
    compute_s = flops / hw.peak_flops
    memory_s = bytes_accessed / hw.hbm_bw
    return {
        "flops": float(flops),
        "bytes": float(bytes_accessed),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "roofline_s": max(compute_s, memory_s),
        "bound": "compute" if compute_s >= memory_s else "memory",
        "intensity": float(flops) / max(float(bytes_accessed), 1.0),
        "ridge_intensity": hw.peak_flops / hw.hbm_bw,
    }


def achieved_fraction(measured_s: float, roofline_s: float) -> float:
    """Fraction of the roofline bound achieved: bound / measured, in [0, 1]
    on real hardware. Interpret-mode runs report tiny fractions -- the
    bench JSON tags those with ``interpret: true`` so the perf gate never
    reads an interpret fraction as a Mosaic regression."""
    return float(roofline_s) / max(float(measured_s), 1e-12)


def model_flops(cfg: ArchConfig, tokens: int, kind: str) -> float:
    """6*N*D useful-FLOPs reference for ``tokens`` processed tokens.

    train: 6*N*D (fwd+bwd). prefill: 2*N*D. decode: 2*N_active*D per token.
    MoE uses active params.
    """
    n = active_param_count(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def analytic_flops_per_token(cfg: ArchConfig, seq_len: int, kind: str) -> float:
    """Finer-grained forward FLOPs/token including attention O(s) term."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    per_layer = 0.0
    if cfg.has_attention:
        per_layer += 2 * d * hd * (2 * H + 2 * KV)            # qkvo projections
        kv_span = min(cfg.sliding_window or seq_len, seq_len)
        per_layer += 2 * 2 * H * hd * (kv_span / 2 if kind != "decode" else kv_span)
    if cfg.has_ssm:
        di, n, h, p = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        per_layer += 2 * d * (2 * di + 2 * n + h) + 2 * di * d
        Lc = cfg.ssm_chunk
        per_layer += 2 * Lc * n + 2 * Lc * h * p + 4 * h * p * n
    if cfg.is_moe:
        per_layer += 2 * 3 * d * f * cfg.top_k * cfg.capacity_factor + 2 * d * cfg.n_experts
    elif cfg.d_ff:
        nmat = 2 if cfg.norm == "ln" else 3
        per_layer += 2 * nmat * d * f
    total = per_layer * cfg.n_layers + 2 * d * cfg.vocab      # lm head
    if kind == "train":
        total *= 3 + (1 if cfg.remat else 0)                   # bwd + remat fwd
    return total
