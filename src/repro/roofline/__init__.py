from repro.roofline.hlo import parse_hlo_costs, compiled_costs, HloCosts
from repro.roofline.model import (RooflineTerms, roofline_from_costs, HW,
                                  analytic_flops_per_token, model_flops,
                                  kernel_roofline, achieved_fraction)

__all__ = ["parse_hlo_costs", "compiled_costs", "HloCosts", "RooflineTerms",
           "roofline_from_costs", "HW", "analytic_flops_per_token",
           "model_flops", "kernel_roofline", "achieved_fraction"]
