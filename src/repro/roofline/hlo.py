"""Post-optimization HLO cost extraction with while-loop trip accounting.

``compiled.cost_analysis()`` counts each while (lax.scan) body ONCE -- for a
64-layer scanned model that under-counts FLOPs by 64x. This module parses
``compiled.as_text()`` instead:

* builds the computation call graph (fusions via ``calls=``/``to_apply=``,
  whiles via ``body=``/``condition=``),
* extracts each while's trip count from the constant bound in its condition
  computation,
* walks from ENTRY with multiplicative trip factors, accumulating
    - dot/convolution FLOPs (from output shape x contracting dims),
    - fusion/dot/collective I/O bytes (post-fusion memory-traffic proxy),
    - collective bytes by kind (all-gather / all-reduce / reduce-scatter /
      all-to-all / collective-permute), all-reduce counted 2x (RS+AG).

All numbers are PER-DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+"
                       r"([\w\-]+)\((.*)$")
# computation header: "%name (args...) -> type {"; args may contain nested
# parens (tuple-typed while-body params), so just grab the leading name.
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (sums tuple elements)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclass
class _Computation:
    name: str
    instrs: list = field(default_factory=list)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_count: int = 0
    while_trips: dict = field(default_factory=dict)

    def add_kind(self, kind: str, b: float):
        self.collective_by_kind[kind] = self.collective_by_kind.get(kind, 0.0) + b


def _parse_computations(text: str) -> tuple[dict[str, _Computation], str | None]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_RE.match(line)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if cur is None or "=" not in stripped:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(_Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps, entry


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m:
        return 2.0 * out_elems  # unknown: assume rank-1 contraction
    lhs_name = _first_operand(instr.rest)
    lhs_type = shapes.get(lhs_name, "")
    sm = _SHAPE_RE.search(lhs_type)
    contracted = 1
    if sm:
        dims = [int(d) for d in sm.group(2).split(",") if d]
        for ci in m.group(1).split(","):
            if ci and int(ci) < len(dims):
                contracted *= dims[int(ci)]
    return 2.0 * out_elems * contracted


def _first_operand(rest: str) -> str:
    """First operand NAME. Operand lists come in two dialects:
    bare (``%a, %b)``) and typed (``f32[128,512]{1,0} %a, ...)``) -- in the
    typed dialect the leading token is the dtype, so prefer the first
    %-prefixed name and only fall back to the leading bare word."""
    ops = _operand_names(rest)
    if ops:
        return ops[0]
    m = re.match(r"\s*([\w\.\-]+)", rest)
    return m.group(1) if m else ""


def _operand_names(rest: str) -> list[str]:
    # operands are at the start of rest: "%a, %b, %c), attrs..."
    out = []
    depth = 0
    for tok in re.finditer(r"%([\w\.\-]+)|([(),])", rest):
        if tok.group(2) == "(":
            depth += 1
        elif tok.group(2) == ")":
            if depth == 0:
                break
            depth -= 1
        elif tok.group(1):
            out.append(tok.group(1))
    return out


def _while_trip(cond: _Computation) -> int:
    """Trip count = the constant bound in the condition computation."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def parse_hlo_costs(text: str) -> HloCosts:
    comps, entry_name = _parse_computations(text)
    shapes: dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.type_str

    if entry_name is None:  # fall back: the computation containing a while
        entry_name = max(comps, key=lambda n: len(comps[n].instrs))

    costs = HloCosts()

    # ---- fusion input sizing -------------------------------------------
    # Inside a layer-scan while body, fusions receive the FULL stacked
    # (n_layers, ...) weight arrays as operands but only dynamic-slice one
    # layer's worth per trip. Counting the full operand would overcount
    # HBM traffic by ~n_layers x. For each fused computation, map
    # parameter index -> bytes actually consumed: if a parameter feeds
    # only dynamic-slice ops, charge the slice output size instead.
    _fusion_in_memo: dict[str, dict[int, int]] = {}

    def fusion_param_bytes(comp_name: str) -> dict[int, int]:
        if comp_name in _fusion_in_memo:
            return _fusion_in_memo[comp_name]
        out: dict[int, int] = {}
        comp = comps.get(comp_name)
        if comp is None:
            return out
        params: dict[str, int] = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    params[ins.name] = int(m.group(1))
        sliced: dict[int, int] = {}
        consumed_other: set[int] = set()
        for ins in comp.instrs:
            ops = _operand_names(ins.rest)
            for o in ops:
                if o not in params:
                    continue
                idx = params[o]
                if ins.opcode == "dynamic-slice" and ops and ops[0] == o:
                    sliced[idx] = sliced.get(idx, 0) + _shape_bytes(ins.type_str)
                else:
                    consumed_other.add(idx)
        for name, idx in params.items():
            if idx in sliced and idx not in consumed_other:
                out[idx] = sliced[idx]
        _fusion_in_memo[comp_name] = out
        return out

    # ---- memoized per-computation unit costs (multiplier-invariant) ----
    _dot_memo: dict[str, float] = {}

    def dot_flops_of(comp_name: str, stack=()) -> float:
        """Dot/conv FLOPs inside a computation incl. nested fusions (x1)."""
        if comp_name in _dot_memo:
            return _dot_memo[comp_name]
        if comp_name not in comps or comp_name in stack:
            return 0.0
        total = 0.0
        for ins in comps[comp_name].instrs:
            if ins.opcode in ("dot", "convolution"):
                total += _dot_flops(ins, shapes)
            elif ins.opcode in ("fusion", "call", "custom-call"):
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.rest):
                    total += dot_flops_of(m.group(1), stack + (comp_name,))
        _dot_memo[comp_name] = total
        return total

    _full_memo: dict[str, tuple] = {}

    def full_costs_of(comp_name: str, stack=()) -> tuple:
        """(flops, bytes, coll_bytes, coll_count, kinds) of one execution."""
        if comp_name in _full_memo:
            return _full_memo[comp_name]
        if comp_name not in comps or comp_name in stack:
            return (0.0, 0.0, 0.0, 0, {})
        fl = by = cb = 0.0
        cc = 0
        kinds: dict[str, float] = {}
        for ins in comps[comp_name].instrs:
            op = ins.opcode
            if op in ("dot", "convolution"):
                fl += _dot_flops(ins, shapes)
                by += _shape_bytes(ins.type_str) + sum(
                    _shape_bytes(shapes.get(o, "")) for o in _operand_names(ins.rest))
            elif op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                overrides = fusion_param_bytes(m.group(1)) if m else {}
                in_bytes = 0
                for i, o in enumerate(_operand_names(ins.rest)):
                    in_bytes += overrides.get(i, _shape_bytes(shapes.get(o, "")))
                by += _shape_bytes(ins.type_str) + in_bytes
                if m:
                    fl += dot_flops_of(m.group(1))
            elif any(op.startswith(c) for c in COLLECTIVES):
                if op.endswith("-done"):
                    continue
                base = next(c for c in COLLECTIVES if op.startswith(c))
                b = _shape_bytes(ins.type_str)
                if base == "all-reduce":
                    b *= 2  # RS + AG
                cb += b
                cc += 1
                kinds[base] = kinds.get(base, 0.0) + b
                by += _shape_bytes(ins.type_str)
            elif op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                trips = _while_trip(comps[cm.group(1)]) if cm and cm.group(1) in comps else 1
                costs.while_trips.setdefault(ins.name, trips)
                if bm:
                    sfl, sby, scb, scc, skinds = full_costs_of(
                        bm.group(1), stack + (comp_name,))
                    fl += trips * sfl
                    by += trips * sby
                    cb += trips * scb
                    cc += trips * scc
                    for k, v in skinds.items():
                        kinds[k] = kinds.get(k, 0.0) + trips * v
            elif op in ("call", "conditional", "async-start"):
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.rest):
                    sfl, sby, scb, scc, skinds = full_costs_of(
                        m.group(1), stack + (comp_name,))
                    fl += sfl
                    by += sby
                    cb += scb
                    cc += scc
                    for k, v in skinds.items():
                        kinds[k] = kinds.get(k, 0.0) + v
        out = (fl, by, cb, cc, kinds)
        _full_memo[comp_name] = out
        return out

    fl, by, cb, cc, kinds = full_costs_of(entry_name)
    costs.flops = fl
    costs.bytes_accessed = by
    costs.collective_bytes = cb
    costs.collective_count = cc
    costs.collective_by_kind = kinds
    return costs


def compiled_costs(fn, *args, **kwargs) -> HloCosts:
    """Jit-compile ``fn(*args, **kwargs)`` and parse its optimized HLO.

    The cross-check path for the kernels' analytic ``CostEstimate``s: run
    the XLA *reference* implementation (e.g. ``kernels.ref.fedavg_agg``)
    through this and compare its bytes/FLOPs against the analytic model --
    if the reference program moves fewer bytes than the kernel claims, the
    claim is wrong. Numbers are per-device, post-optimization.
    """
    import jax

    compiled = jax.jit(fn).lower(*args, **kwargs).compile()
    return parse_hlo_costs(compiled.as_text())
