"""Shared federated-learning machinery: jit'd local client training.

A *client update* is E epochs of mini-batch training (the paper uses Adam,
lr=1e-3 on EMNIST; SGD on CINIC) on the client's local (padded, masked)
dataset, starting from supplied weights. It is the unit both FedAvg
(clients in parallel from the same start weights) and Astraea mediators
(clients sequentially, each from the previous client's weights) compose.

All shapes are static: client datasets are padded to a common length that
is a multiple of the batch size, with a 0/1 sample mask excluded from the
loss. Dummy (all-padding) clients are exact no-ops -- masked loss is 0, so
gradients and hence Adam updates vanish -- which is what lets mediators be
padded to a fixed gamma and vmapped.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.cnn import Model, cross_entropy_loss
from repro.optim.optimizers import Optimizer, apply_updates

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class LocalSpec:
    """Static local-training hyperparameters (paper TABLE II: B, E)."""
    batch_size: int
    epochs: int


def _loss_fn(model: Model, params: PyTree, x: Array, y: Array, mask: Array,
             key: Array) -> Array:
    logits = model.apply(params, x, train=True, rngs=key)
    return cross_entropy_loss(logits, y, mask)


def make_client_update(model: Model, opt: Optimizer, spec: LocalSpec,
                       loss_fn: Callable | None = None
                       ) -> Callable[[PyTree, Array, Array, Array, Array], PyTree]:
    """Build the jit-able client-update function.

    Returns ``client_update(params, x, y, mask, key) -> params`` running
    ``spec.epochs`` epochs of mini-batch steps over the padded local data.
    ``loss_fn(model, params, x, y, mask, key)`` defaults to masked CE
    (cost-sensitive variants pass their own -- core.reweighting).
    """
    grad_fn = jax.grad(partial(loss_fn or _loss_fn, model))

    def client_update(params: PyTree, x: Array, y: Array, mask: Array,
                      key: Array) -> PyTree:
        n_pad = x.shape[0]
        bsz = spec.batch_size
        assert n_pad % bsz == 0, "pad client data to a multiple of batch_size"
        nb = n_pad // bsz
        opt_state = opt.init(params)

        def epoch_body(carry, ekey):
            params, opt_state = carry
            perm_key, *step_keys = jax.random.split(ekey, nb + 1)
            perm = jax.random.permutation(perm_key, n_pad)
            xs = x[perm].reshape(nb, bsz, *x.shape[1:])
            ys = y[perm].reshape(nb, bsz)
            ms = mask[perm].reshape(nb, bsz)

            def step_body(carry, batch):
                params, opt_state = carry
                bx, by, bm, bkey = batch
                grads = grad_fn(params, bx, by, bm, bkey)
                updates, opt_state = opt.update(grads, opt_state, params)
                return (apply_updates(params, updates), opt_state), None

            (params, opt_state), _ = jax.lax.scan(
                step_body, (params, opt_state), (xs, ys, ms, jnp.stack(step_keys)))
            return (params, opt_state), None

        ekeys = jax.random.split(key, spec.epochs)
        (params, _), _ = jax.lax.scan(epoch_body, (params, opt_state), ekeys)
        return params

    return client_update


def weighted_average(trees: PyTree, weights: Array) -> PyTree:
    """FedAvg Eq. 6: sum_k (n_k / n) tree_k over a stacked-leading-axis pytree."""
    wnorm = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def avg(leaf):
        return jnp.tensordot(wnorm, leaf, axes=1).astype(leaf.dtype)

    return jax.tree.map(avg, trees)


def confusion_matrix(model: Model, params: PyTree, x, y, num_classes: int,
                     batch_size: int = 512):
    """Paper Fig. 1(b)/(c): row-normalized confusion matrix + per-class
    recall -- under global imbalance the minority-class rows go grey."""
    import numpy as np

    @jax.jit
    def preds(params, bx):
        return jnp.argmax(model.apply(params, bx, train=False), -1)

    cm = np.zeros((num_classes, num_classes), np.int64)
    n = x.shape[0]
    for start in range(0, n, batch_size):
        p = np.asarray(preds(params, jnp.asarray(x[start:start + batch_size])))
        t = np.asarray(y[start:start + batch_size])
        np.add.at(cm, (t, p), 1)
    recall = cm.diagonal() / np.maximum(cm.sum(axis=1), 1)
    return cm, recall


def evaluate(model: Model, params: PyTree, x: Array, y: Array,
             batch_size: int = 512) -> dict[str, float]:
    """Top-1 accuracy + loss on a (balanced) test set."""
    n = x.shape[0]
    correct, loss_sum = 0.0, 0.0

    @jax.jit
    def batch_stats(params, bx, by):
        logits = model.apply(params, bx, train=False)
        acc = jnp.sum((jnp.argmax(logits, -1) == by).astype(jnp.float32))
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, by[:, None], axis=-1).sum()
        return acc, nll

    for start in range(0, n, batch_size):
        bx = x[start:start + batch_size]
        by = y[start:start + batch_size]
        acc, nll = batch_stats(params, jnp.asarray(bx), jnp.asarray(by))
        correct += float(acc)
        loss_sum += float(nll)
    return {"accuracy": correct / n, "loss": loss_sum / n}
