"""Astraea core: the paper's contribution as composable JAX modules."""
from repro.core import distribution, augmentation, scheduling, fl, comm
from repro.core import client_store, staleness
from repro.core.astraea import AstraeaTrainer
from repro.core.async_engine import AsyncRoundEngine, AsyncSpec
from repro.core.client_store import ClientStore, build_client_store
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fedavg import FedAvgTrainer
from repro.core.fl import LocalSpec
from repro.core.staleness import StragglerModel, StragglerSpec

__all__ = ["distribution", "augmentation", "scheduling", "fl", "comm",
           "client_store", "staleness", "AstraeaTrainer", "AsyncRoundEngine",
           "AsyncSpec", "ClientStore", "build_client_store", "EngineConfig",
           "FLRoundEngine", "FedAvgTrainer", "LocalSpec", "StragglerModel",
           "StragglerSpec"]
