"""Astraea core: the paper's contribution as composable JAX modules."""
from repro.core import distribution, augmentation, scheduling, fl, comm
from repro.core.astraea import AstraeaTrainer
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fedavg import FedAvgTrainer
from repro.core.fl import LocalSpec

__all__ = ["distribution", "augmentation", "scheduling", "fl", "comm",
           "AstraeaTrainer", "EngineConfig", "FLRoundEngine", "FedAvgTrainer",
           "LocalSpec"]
