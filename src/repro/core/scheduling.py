"""Algorithm 3 — Mediator based multi-client rescheduling.

Greedy strategy: a mediator repeatedly absorbs the unassigned client whose
label histogram brings the mediator's *merged* distribution closest to
uniform (min ``D_KL(P_m + P_k || P_u)``), until it holds ``gamma`` clients;
then a fresh mediator is created, until no clients remain.

Two implementations, identical mediator lists:

* ``impl="batched"`` (default) — one jitted ``lax.scan`` over the K
  absorption steps with ``client_counts`` held device-resident and
  assigned clients masked to ``+inf``; a single device roundtrip per
  reschedule. The historical per-step dispatch (one
  ``merged_kld_scores`` call + host argmin per absorbed client) cost
  O(K) roundtrips — O(K^2) score work issued from the interpreter — and
  stalled Alg. 3 for minutes at K=1e5. With ``use_kernel=True`` the
  whole pass instead runs as ONE Pallas launch
  (``kernels.kld_greedy_picks``): the masked-argmin sweep, pick commit,
  histogram fold and gamma-reset all live in kernel scratch, so a
  scheduling pass issues O(1) ``pallas_call``s instead of the historical
  O(M·gamma) per-step kernel dispatches.
* ``impl="loop"`` — the numpy greedy loop (exact Alg. 3 as in the paper;
  kept as the equivalence oracle, and as the path that drives the Pallas
  ``kld_score`` kernel one launch per greedy step via
  ``use_kernel=True`` — the historical O(M·gamma)-launch pattern).

All paths tie-break identically: the loop's ``argmin`` returns the first
minimum over the unassigned list, which stays in ascending client order;
the masked argmin (scan and kernel alike) returns the lowest client id
among the minima. Scores match bitwise because every path casts counts
to f32 and replays the same ``merged_kld_scores`` op sequence, and label
counts are integer-valued (< 2^24), where f32 accumulation is exact.

We also provide ``random_schedule`` (the FedAvg-style control: clients
grouped arbitrarily) for the ablations in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import distribution as dist


@dataclass
class Mediator:
    """One mediator's schedule: ordered client ids + merged label counts."""
    clients: list[int] = field(default_factory=list)
    counts: np.ndarray | None = None

    def kld_to_uniform(self) -> float:
        return float(dist.kld_to_uniform(jnp.asarray(self.counts)))


def _score_candidates(mediator_counts: np.ndarray, candidate_counts: np.ndarray,
                      *, use_kernel: bool = False) -> np.ndarray:
    """D_KL(normalize(P_m + P_k) || U) for every candidate k."""
    if use_kernel:
        from repro.kernels import ops as kops
        return np.asarray(kops.kld_score(jnp.asarray(mediator_counts, jnp.float32),
                                         jnp.asarray(candidate_counts, jnp.float32)))
    return np.asarray(dist.merged_kld_scores(jnp.asarray(mediator_counts, jnp.float32),
                                             jnp.asarray(candidate_counts, jnp.float32)))


@partial(jax.jit, static_argnames="gamma")
def _greedy_picks(counts_f32: jnp.ndarray, gamma: int) -> jnp.ndarray:
    """Device-resident Alg. 3 inner loop: the full absorption order.

    One ``lax.scan`` step per absorbed client -- score every client
    against the open mediator (assigned ones masked to ``+inf``), take
    the first-minimum argmin (the loop's tie-break), absorb, and reset
    the mediator histogram after every ``gamma`` picks."""
    k = counts_f32.shape[0]

    def step(carry, _):
        assigned, med, fill = carry
        scores = dist.merged_kld_scores(med, counts_f32)
        pick = jnp.argmin(jnp.where(assigned, jnp.inf, scores))
        merged = med + counts_f32[pick]
        full = fill + 1 == gamma
        med = jnp.where(full, jnp.zeros_like(merged), merged)
        fill = jnp.where(full, 0, fill + 1)
        return (assigned.at[pick].set(True), med, fill), pick

    init = (jnp.zeros(k, bool), jnp.zeros(counts_f32.shape[1], jnp.float32),
            jnp.asarray(0, jnp.int32))
    return jax.lax.scan(step, init, None, length=k)[1]


def reschedule(client_counts: np.ndarray, gamma: int, *,
               use_kernel: bool = False, impl: str = "auto") -> list[Mediator]:
    """Alg. 3: partition clients into mediators of size <= gamma.

    Args:
      client_counts: ``(K, C)`` per-client label histograms (the only thing
        clients share -- never samples).
      gamma: max clients per mediator.
      use_kernel: run the scoring through Pallas. Under ``"batched"`` the
        ENTIRE pass is one ``kld_greedy_picks`` launch; under ``"loop"``
        the numpy loop drives one ``kld_score`` launch per greedy step
        (the historical O(M·gamma)-launch pattern, kept as an oracle).
      impl: ``"batched"`` (device-resident; one executable dispatch per
        reschedule), ``"loop"`` (numpy greedy oracle), or ``"auto"``
        (batched). All produce identical mediator lists.

    Returns:
      List of ``Mediator``; every client appears in exactly one.
    """
    if impl not in ("auto", "batched", "loop"):
        raise ValueError(f"unknown reschedule impl {impl!r}")
    if impl == "auto":
        impl = "batched"
    client_counts = np.asarray(client_counts, np.float64)
    num_clients, num_classes = client_counts.shape
    if num_clients == 0:
        return []
    if impl == "batched":
        counts_f32 = jnp.asarray(client_counts, jnp.float32)
        if use_kernel:
            from repro.kernels import ops as kops
            picks = np.asarray(kops.kld_greedy_picks(counts_f32, int(gamma)))
        else:
            picks = np.asarray(_greedy_picks(counts_f32, int(gamma)))
        return [Mediator(clients=[int(c) for c in picks[s:s + gamma]],
                         counts=client_counts[picks[s:s + gamma]].sum(0))
                for s in range(0, num_clients, gamma)]
    unassigned = list(range(num_clients))
    mediators: list[Mediator] = []
    while unassigned:
        med = Mediator(counts=np.zeros(num_classes))
        while unassigned and len(med.clients) < gamma:
            cand = client_counts[unassigned]                      # (k, C)
            scores = _score_candidates(med.counts, cand, use_kernel=use_kernel)
            best = int(np.argmin(scores))
            cid = unassigned.pop(best)
            med.clients.append(cid)
            med.counts = med.counts + client_counts[cid]
        mediators.append(med)
    return mediators


def random_schedule(num_clients: int, gamma: int, client_counts: np.ndarray,
                    seed: int = 0) -> list[Mediator]:
    """Control: arbitrary grouping (what plain FedAvg round batching does)."""
    client_counts = np.asarray(client_counts, np.float64)
    rng = np.random.default_rng(seed)
    order = rng.permutation(num_clients)
    mediators = []
    for start in range(0, num_clients, gamma):
        ids = [int(i) for i in order[start:start + gamma]]
        med = Mediator(clients=ids, counts=client_counts[ids].sum(0))
        mediators.append(med)
    return mediators


def place_mediators(groups: list[list[int]], num_shards: int,
                    rows_per_shard: int, owner) -> tuple[np.ndarray, dict]:
    """Locality-aware placement: mediators -> shard rows (sharded ClientStore).

    With the client store partitioned over ``num_shards`` devices, a
    mediator's ``x_all[idx]`` gather is free for clients its own device
    holds and costs an ``all_gather`` slot for every remote one. This pass
    assigns each mediator (a list of client ids) to the shard owning the
    most of its clients, subject to ``rows_per_shard`` capacity per shard --
    greedy in descending *regret* (best-shard count minus runner-up), so
    mediators with the most to lose from a bad placement pick first.
    Deterministic: ties broken by mediator index, then shard index.

    Args:
      groups: per-mediator client-id lists (scheduling order).
      num_shards: mediator mesh size ``n``.
      rows_per_shard: ``M_pad // n`` mediator rows available per shard.
      owner: callable mapping a client id to its owning shard.

    Returns:
      ``(row_to_group, stats)``: ``row_to_group`` is an ``(n * rows_per_shard,)``
      int array giving the original mediator index occupying each row
      (``-1`` = dummy row; rows ``[d * rows_per_shard, (d+1) * rows_per_shard)``
      execute on shard ``d``), and ``stats`` counts local vs cross-shard
      client fetches under this placement.
    """
    m = len(groups)
    m_pad = num_shards * rows_per_shard
    if m > m_pad:
        raise ValueError(f"{m} mediators do not fit {num_shards}x"
                         f"{rows_per_shard} shard rows")
    counts = np.zeros((m, num_shards), np.int64)
    for g, clients in enumerate(groups):
        for cid in clients:
            counts[g, owner(cid)] += 1

    def regret(g: int) -> int:
        row = np.sort(counts[g])
        return int(row[-1] - (row[-2] if num_shards > 1 else 0))

    capacity = [rows_per_shard] * num_shards
    shard_of = np.zeros(m, np.int64)
    local = 0
    for g in sorted(range(m), key=lambda g: -regret(g)):
        prefs = np.argsort(-counts[g], kind="stable")
        s = next(int(s) for s in prefs if capacity[s] > 0)
        capacity[s] -= 1
        shard_of[g] = s
        local += int(counts[g, s])
    row_to_group = np.full(m_pad, -1, np.int64)
    next_row = [d * rows_per_shard for d in range(num_shards)]
    for g in range(m):                      # mediator order within a shard
        d = int(shard_of[g])
        row_to_group[next_row[d]] = g
        next_row[d] += 1
    total = int(sum(len(c) for c in groups))
    stats = {"local_fetches": local, "remote_fetches": total - local,
             "total_fetches": total, "num_shards": num_shards}
    return row_to_group, stats


def partition_waves(durations: np.ndarray, wave_size: int
                    ) -> tuple[list[list[int]], dict]:
    """Straggler-aware wave placement for the async round engine.

    Sorts mediators by simulated duration (stable, so ties keep schedule
    order) and chunks them into waves of ``wave_size`` -- co-scheduling
    slow mediators into the *late* waves so the fast waves are never
    blocked behind a straggler. A wave completes when its slowest member
    does, so sorted chunking minimizes the sum of wave completion times
    over all contiguous partitions of a fixed wave size.

    This composes with *client*-level heterogeneity without any code
    here changing: a ``StragglerSpec(level="client")`` model derives
    each mediator's duration as the sum of its members' factors
    (``StragglerModel.durations_for_groups``), so a slow *device* drags
    whichever mediator Alg. 3 packed it into toward the late waves --
    speed-aware wave placement stacks on top of KLD-greedy packing
    rather than perturbing it. With all clients at unit speed the
    durations tie everywhere and the stable sort reproduces the
    historical mediator-only ordering bitwise.

    Args:
      durations: ``(M,)`` simulated per-mediator training times
        (schedule order; see ``core/staleness.py``).
      wave_size: mediators per wave; ``<= 0`` means one wave holding the
        whole fleet (the synchronous barrier, degenerate case).

    Returns:
      ``(waves, stats)``: ``waves`` is a list of schedule-index lists in
      completion order (fastest wave first); ``stats`` reports per-wave
      completion times, the synchronous barrier time (max duration), and
      ``blocked_time_saved`` -- the reduction in summed wave completion
      times vs chunking in arbitrary (schedule) order, i.e. what
      co-scheduling the stragglers bought.
    """
    durations = np.asarray(durations, np.float64)
    m = int(durations.shape[0])
    if m == 0:
        raise ValueError("cannot partition zero mediators into waves")
    ws = wave_size if wave_size and wave_size > 0 else m
    order = np.argsort(durations, kind="stable")
    waves = [[int(i) for i in order[s:s + ws]] for s in range(0, m, ws)]
    wave_times = [float(durations[w].max()) for w in waves]
    naive_times = [float(durations[s:s + ws].max()) for s in range(0, m, ws)]
    stats = {
        "num_waves": len(waves),
        "wave_times": wave_times,
        "barrier_time": float(durations.max()),
        "blocked_time_saved": float(sum(naive_times) - sum(wave_times)),
    }
    return waves, stats


def schedule_stats(mediators: list[Mediator]) -> dict[str, float]:
    """Fig. 7 metrics: distribution of D_KL(P_m || P_u) over mediators.

    These keys are an observability surface, not just a return value: the
    engine stores them as ``last_schedule_stats`` (with the store's
    placement stats merged under a disjoint ``store_`` prefix) and the
    telemetry layer republishes each one as an ``astraea_schedule_<key>``
    / ``astraea_store_<key>`` gauge every round -- renaming a key here
    renames the exported metric.
    """
    klds = np.array([m.kld_to_uniform() for m in mediators])
    return {
        "kld_mean": float(klds.mean()),
        "kld_median": float(np.median(klds)),
        "kld_max": float(klds.max()),
        "kld_min": float(klds.min()),
        "num_mediators": len(mediators),
    }
