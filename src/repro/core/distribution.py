"""Class-distribution statistics and Kullback-Leibler divergence.

Astraea's two strategies both operate on *label distributions*:

* Alg. 2 (augmentation) needs the **global** per-class sample counts
  ``C_1..C_N`` and their mean ``C_bar``.
* Alg. 3 (rescheduling) greedily minimizes ``D_KL(P_m + P_k || P_u)`` where
  ``P_m`` is a mediator's accumulated label distribution, ``P_k`` a candidate
  client's, and ``P_u`` the uniform distribution.

Everything here is pure JAX so it can run jit'd on device (the FL server in
the paper computes this centrally from the clients' reported histograms --
clients only share *label counts*, never samples, preserving the paper's
privacy model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


def class_histogram(labels: Array, num_classes: int, mask: Array | None = None) -> Array:
    """Per-class sample counts of an integer label vector.

    Args:
      labels: int array ``(n,)``.
      mask: optional bool/float array ``(n,)`` -- 0 entries are padding and
        are excluded (client datasets are stored padded to a common length).

    Returns:
      float32 ``(num_classes,)`` counts.
    """
    weights = jnp.ones(labels.shape, jnp.float32) if mask is None else mask.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    return jnp.einsum("n,nc->c", weights, onehot)


def normalize(counts: Array) -> Array:
    """Counts -> probability distribution (safe for all-zero rows)."""
    total = jnp.sum(counts, axis=-1, keepdims=True)
    return counts / jnp.maximum(total, _EPS)


def uniform(num_classes: int) -> Array:
    return jnp.full((num_classes,), 1.0 / num_classes, jnp.float32)


def kl_divergence(p: Array, q: Array) -> Array:
    """D_KL(p || q) with the 0·log(0/q) = 0 convention.

    ``p`` and ``q`` are distributions over the last axis; broadcasting over
    leading axes is supported (used to score many candidate clients at once).
    """
    p = jnp.asarray(p, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    ratio = jnp.log(jnp.maximum(p, _EPS)) - jnp.log(jnp.maximum(q, _EPS))
    return jnp.sum(jnp.where(p > 0, p * ratio, 0.0), axis=-1)


def kld_to_uniform(counts: Array) -> Array:
    """D_KL(normalize(counts) || U). Accepts leading batch axes."""
    num_classes = counts.shape[-1]
    return kl_divergence(normalize(counts), uniform(num_classes))


def merged_kld_scores(mediator_counts: Array, client_counts: Array) -> Array:
    """Alg. 3 inner loop, vectorized: score every candidate client.

    Args:
      mediator_counts: ``(C,)`` current per-class counts held by the mediator.
      client_counts: ``(K, C)`` per-class counts of the candidate clients.

    Returns:
      ``(K,)`` -- ``D_KL(normalize(P_m + P_k) || P_u)`` per candidate.
    """
    merged = mediator_counts[None, :] + client_counts
    return kld_to_uniform(merged)


def global_histogram(client_counts: Array) -> Array:
    """Union distribution over all clients: sum of per-client counts."""
    return jnp.sum(client_counts, axis=0)


def imbalance_summary(client_counts: Array) -> dict[str, Array]:
    """Diagnostics used by EXPERIMENTS.md: the three imbalance types."""
    sizes = jnp.sum(client_counts, axis=-1)                      # scalar imbalance
    local_kld = kld_to_uniform(client_counts)                    # local imbalance
    global_kld = kld_to_uniform(global_histogram(client_counts))  # global imbalance
    return {
        "size_cv": jnp.std(sizes) / jnp.maximum(jnp.mean(sizes), _EPS),
        "local_kld_mean": jnp.mean(local_kld),
        "global_kld": global_kld,
    }
