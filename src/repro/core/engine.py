"""Sharded FL round engine: pack once, gather on device, shard mediators.

One engine drives both algorithms in this repo:

* **Astraea** (paper Alg. 1/3): KLD-greedy mediator schedule, up to ``gamma``
  clients per mediator trained *sequentially* for ``E_m`` mediator epochs,
  FedAvg aggregation (Eq. 6) over the mediator weight *deltas*.
* **FedAvg** (the baseline): exactly the ``gamma=1`` + random-singleton
  schedule + full-weight aggregation configuration of the same engine --
  every "mediator" holds one client training from the global weights.

What makes it an engine rather than a trainer loop:

1. **Pack once, place by policy.** The padded per-client arrays
   ``(K, pad, ...)`` are packed at construction into a ``ClientStore``
   (``core/client_store.py``) under one of four placement policies:

   * ``replicated`` -- the whole store on every device. Fastest gathers;
     per-device bytes = K * slice, so K is bounded by one device's HBM.
   * ``sharded`` -- client axis partitioned over the ``mediator`` mesh
     axis (per-device bytes = K/n * slice). Each mediator's ``x_all[idx]``
     gather is routed at schedule time: locally-owned clients read from
     the device's shard; remote ones ride the serve-slice exchange --
     by default a *ragged* point-to-point ppermute ring that ships each
     slice only to the shards whose rows read it
     (``cfg.store_exchange="ragged"``), or the historical fixed-capacity
     ``all_gather`` of every shard's full serve buffer (``"gather"``);
     both are static-shaped across reschedules and bit-identical.
     Mediator rows are placed by the locality pass
     ``scheduling.place_mediators`` to minimize cross-shard fetches.
   * ``host`` -- the federation stays in host RAM (per-device bytes =
     min(K, c) * slice); the unique scheduled clients are streamed to
     device once per reschedule into a fixed-capacity compact buffer.
   * ``spilled`` -- the streaming contract of ``host`` with the
     federation itself demoted to a disk/mmap tier (or a lazy row
     source) behind a ``min(K, c)``-row RAM cache; when rescheduling
     every round, the engine pre-draws the NEXT round's selection and
     hands it to ``store.prefetch`` so the tier reads overlap the
     current round's device compute (the rng draw order is unchanged,
     so trajectories stay bitwise identical).

   The engine also accepts a *streaming federation* instead of packed
   arrays -- any ``data`` without ``client_images`` but with the row-
   source protocol (``rows(ids)``/``num_clients``/``nbytes_per_client``
   + ``pad``/``num_classes``/``client_counts()``; see
   ``data.synthetic.StreamingFederation``) feeds the host/spilled
   stores directly, so a K=1e6 federation runs rounds on a device (and
   host) footprint fixed by ``clients_per_round``, never by K.

   A schedule is a tiny ``(M, gamma)`` int32 gather index plus a 0/1 slot
   mask; ``run_round`` never rebuilds host buffers (the old trainers
   re-packed ``(M, gamma, pad, ...)`` on the host every round). Slot-mask
   zeros make empty client slots exact no-ops (masked loss is 0 => zero
   grads => zero Adam updates), so a dummy slot may harmlessly gather any
   resident row.  Store traffic is metered: host->device streaming and
   the sharded serve exchange land on the CommMeter's intra-pod ledger
   (``store_stream`` / ``store_exchange``); the WAN ledger is invariant
   to placement policy.
2. **Mediator sharding.** Mediators are distributed over the ``mediator``
   axis of a device mesh via shard_map; ``M`` is padded up to the mesh
   size with zero-weight dummy mediators (also exact no-ops). On a 1-device
   CPU mesh this degrades to plain vmap semantics bit-for-bit.
3. **Fixed-M compilation.** ``pad_mediators_to`` fixes the padded mediator
   count across reschedules (the trainers default it to ``ceil(c/gamma)``),
   and every store keeps its plan shapes static, so the round executable
   is traced exactly once per engine no matter how often the KLD schedule
   changes -- ``num_round_traces`` counts traces and is asserted in tests.
4. **Donated params.** The round executable receives the parameter buffer
   with ``donate_argnums`` so the server-side update is in-place on
   accelerators.
5. **Kernel aggregation.** ``use_kernel_agg`` routes Eq. 6 through the
   ``fedavg_agg`` Pallas kernel (interpret-mode on CPU, Mosaic on TPU);
   default is the pure-jnp ``weighted_average`` (same math, XLA-fused).
6. **Wave entry point.** ``wave_fn`` is the same full padded-M program
   stopped just before aggregation, for the bounded-staleness async
   subsystem (``core/async_engine.py``): a wave zeroes the slot rows of
   mediators outside it (exact no-ops, like dummy mediators), so the one
   trace serves every wave of every reschedule and ``num_round_traces``
   stays 1 for an async engine too.
7. **Online rebalancing.** When the engine is built with an ``aug_plan``
   (the server's tiny ``(num_classes,)`` Alg. 2 array, broadcast once and
   fed into the jitted round as a true operand), each mediator row's
   per-slot data is passed through ``augmentation.online_augment_batch``
   INSIDE the row program before training: a fixed-shape class-conditional
   resample+warp redrawn every round from round-indexed keys.  The store
   keeps the *raw* clients (per-device bytes stay at the pre-augmentation
   packed size under every placement policy), Alg. 3 schedules on the
   expected post-augmentation histograms ``counts * (1 + plan)``, and the
   Eq. 6 weights become the expected post-augmentation sizes
   ``sum(mask * (1 + plan[y]))``.  Since the hook lives inside the jitted
   round, augmentation adds zero traces: ``num_round_traces`` stays 1,
   including across async waves (aug keys derive from the per-row round
   keys, never from wave membership).  With ``adaptive_aug_alpha`` set,
   the plan is *recomputed from the selected cohort's label histograms at
   every reschedule* (the class-imbalance-FL "rebalance per round"
   regime): the plan is a round operand, so only its value changes -- the
   one compiled executable is reused -- and each re-broadcast is metered
   on the WAN ledger via ``CommMeter.plan_broadcast``.
8. **2-D mediator x model mesh.** On a ``make_fl_mesh(mediator=n,
   model=t)`` mesh the round shard_map is manual over *both* axes: the
   ``model`` axis carries no variation inside the round body (every model
   column runs the identical full-parameter row program -- see below), so
   making it manual costs nothing and sidesteps the XLA-CPU partitioner
   crash that ``lax.scan`` under a partial-auto shard_map trips (the same
   bug family as the remat note in ``launch/dryrun.py``; the transformer
   round keeps ``model`` compiler-auto for true tensor-parallel compute
   and only ever executes that way on TPU meshes).  The sharding
   contract:

   * **params** are sharded along ``model`` by the logical-axis rule
     tables (``param_shardings(model.param_specs(), mesh,
     model_only_rules())``) and replicated along ``mediator`` -- FL
     replicas diverge during a round, so weights never shard over the
     mediator axis.  At rest (between rounds, and in the optimizer-free
     server state) every device holds ``1/t`` of the model: per-device
     param bytes shrink by the model-axis factor (surfaced through
     ``ClientStore.stats()``).
   * **client batches / schedules / keys** are partitioned on
     ``mediator`` and replicated on ``model`` (``P("mediator")`` never
     mentions ``model``); the sharded store's client axis partitions
     over the mediator submesh rows only.
   * **inside the round** the params are gathered to model-replicated
     (``with_sharding_constraint`` -- one all-gather per round), each
     mediator row then runs the *identical full-parameter row program*
     on every model column, and the updated params are resharded back
     onto the model axis on the way out.  Gather and reshard move exact
     bytes and the row program never sees a sharded contraction, so the
     2-D trajectory is bitwise identical to the 1-D one -- ``model=1``
     reproduces today's 1-D trajectories exactly, and with
     ``row_exec="map"`` a ``2x2`` mesh matches a ``4x1`` mesh bit for
     bit across all three stores, sync and async (asserted in
     tests/test_model_mesh.py).  This is residency (ZeRO-style) model
     sharding: compute is replicated along ``model`` while HBM is not --
     the right trade at CNN scale; true tensor-parallel *compute* rides
     the same mesh through ``launch/steps.py:make_fl_round``, which
     delegates its shard_map and Eq. 6 to this module
     (``mediator_shard_map`` / ``psum_eq6``) so there is one federated
     round implementation.
   * **Eq. 6** reduces over the mediator axis only: the stacked mediator
     outputs are constrained to replicated (an all-gather across
     ``mediator``; the ``model`` columns already agree) and the weighted
     average runs in single-device order.  Model-axis collectives are
     accounted on the separate intra-pod ledger
     (``CommMeter.model_axis_round``), never on the WAN ledger that
     backs the paper's 82% traffic claim.

Bit-identity guarantees: every store feeds identical per-slot values into
identical per-row programs (gathers move exact bits), the sharded store's
locality permutation is undone before aggregation (``unperm``), and the
stacked outputs are constrained to replicated sharding first, so the
Eq. 6 reduction always runs in single-device order. Hence at any FIXED
mesh size the three stores produce bitwise-identical trajectories. Across
*different* mesh sizes, XLA's batched kernels are not bit-stable in the
vmap batch width, so the default ``row_exec="vmap"`` matches only to fp
tolerance; ``row_exec="map"`` runs rows through a batch-size-invariant
program and is bitwise identical across any mesh size and store
combination (asserted in tests/test_client_store.py). RNG note: per-round
keys are split at the *real* mediator count before dummy-row padding
(``jax.random.split`` is not prefix-stable) and follow mediators through
placement, so the trajectory is independent of placement policy, and
bit-identical to the pre-engine trainers on a single device.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace as dc_replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import augmentation, scheduling
from repro.core.client_store import EXCHANGES, POLICIES, build_client_store
from repro.core.comm import CommMeter
from repro.core.fl import (LocalSpec, evaluate, make_client_update,
                           weighted_average)
from repro.core.mediator import make_mediator_update
from repro.data.federated import FederatedDataset
from repro.launch.compat import shard_map
from repro.launch.mesh import (default_fl_mesh, model_axis_size,
                               replicated_sharding)
from repro.launch.sharding import model_only_rules, param_shardings
from repro.models import lora as lora_lib
from repro.models.cnn import Model, count_params
from repro.obs.telemetry import as_telemetry
from repro.optim.optimizers import Optimizer

PyTree = Any


def _pad_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# --------------------------------------------------------------------------
# The shared federated-round building blocks (the ONE round implementation:
# FLRoundEngine composes them below; launch/steps.py:make_fl_round delegates
# its transformer round to the same helpers)
# --------------------------------------------------------------------------

def mediator_shard_map(body, mesh, in_specs, out_specs, *,
                       mediator_axes: tuple = ("mediator",),
                       manual_axes: tuple | None = None,
                       check: bool | None = None):
    """shard_map a per-mediator ``body`` over the mediator axes of ``mesh``.

    ``manual_axes`` defaults to ``mediator_axes``: every other mesh axis
    (the tensor-parallel ``model`` axis) then stays compiler-auto, so
    per-mediator model sharding rides along pjit-style -- the transformer
    round's configuration (``launch/steps.py:make_fl_round``).  The FL
    engine instead passes every mesh axis as manual (its model columns
    run identical programs, and XLA-CPU's partitioner crashes on
    ``lax.scan`` under partial-auto -- see the engine docstring §8).
    ``check=None`` keeps the replication checker on for fully-manual
    meshes and disables it under partial-auto, where it cannot reason
    about the auto axes.
    """
    manual = tuple(manual_axes if manual_axes is not None else mediator_axes)
    auto = tuple(a for a in mesh.axis_names if a not in manual)
    if check is None:
        check = not auto
    return shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs,
                     manual_axes=manual, check=check)


def eq6_aggregate(stacked: PyTree, weights, mesh, *,
                  use_kernel_agg: bool = False) -> PyTree:
    """Eq. 6 over stacked ``(M, ...)`` mediator outputs, in a fixed order.

    Constrains the stack to replicated first (the only cross-device
    collective is an all-gather over ``mediator``; the ``model`` columns
    already hold identical rows), so the weighted-average reduction always
    runs in single-device order -- the bit-stability anchor of the whole
    engine.  ``use_kernel_agg`` routes through the fused ``fedavg_agg``
    Pallas kernel instead of the pure-jnp path (same math).
    """
    rep = replicated_sharding(mesh)
    stacked = jax.lax.with_sharding_constraint(stacked, rep)
    weights = jax.lax.with_sharding_constraint(weights, rep)
    if use_kernel_agg:
        from repro.kernels import ops as kops
        return kops.fedavg_agg_tree(stacked, weights)
    return weighted_average(stacked, weights)


def psum_eq6(delta: PyTree, n_m, mediator_axes: tuple) -> PyTree:
    """Eq. 6 *inside* the manual region: weighted psum over the mediator
    axes.  The production-memory-profile variant -- no ``(M, ...)`` stack
    is ever materialized -- used by the transformer round
    (``launch/steps.py:make_fl_round``) on the big meshes, where the
    replicated stack of ``eq6_aggregate`` would not fit."""
    num = jax.tree.map(lambda d: jax.lax.psum(d * n_m, mediator_axes), delta)
    den = jax.lax.psum(n_m, mediator_axes)
    return jax.tree.map(lambda d: d / den, num)


def _per_device_param_bytes(params: PyTree) -> int:
    """Bytes of parameter residency on one device (the first addressable
    one): full leaf bytes when replicated, ``1/model`` when sharded."""
    total = 0
    for leaf in jax.tree.leaves(params):
        shards = getattr(leaf, "addressable_shards", None)
        if not shards:
            total += leaf.nbytes
            continue
        dev = shards[0].device
        total += sum(s.data.nbytes for s in shards if s.device == dev)
    return int(total)


@dataclass(frozen=True)
class EngineConfig:
    """Static round configuration. ``astraea()``/``fedavg()`` build the two
    canonical settings; everything between them is a valid ablation."""
    clients_per_round: int                  # c
    gamma: int                              # max clients per mediator
    local: LocalSpec                        # B, E
    mediator_epochs: int = 1                # E_m
    schedule: str = "kld"                   # "kld" (Alg. 3) | "random"
    aggregate: str = "delta"                # "delta" (Astraea) | "weights" (FedAvg)
    store: str = "replicated"               # client-store placement policy
    # sharded store serve exchange: "ragged" (ppermute ring, exact bytes)
    # or "gather" (historical fixed-capacity all_gather); bit-identical
    store_exchange: str = "ragged"
    # spilled-store streaming pipeline: how many reschedules ahead the
    # engine pre-draws selections and hands them to store.prefetch (the
    # rng draw ORDER is unchanged, so depth never perturbs trajectories),
    # and the host-side LRU row-cache size in rows (None = 2x capacity)
    store_prefetch_depth: int = 1
    store_lru_rows: int | None = None
    # per-device mediator-row execution: "vmap" vectorizes rows (fastest on
    # few devices), "map" runs them serially with a batch-size-invariant
    # program, making trajectories bit-identical across ANY mesh size (XLA
    # batching picks different reduction strategies per batch size, so vmap
    # is only bit-stable at a fixed mesh; see tests/test_client_store.py)
    row_exec: str = "vmap"
    # resampler for the online-augmentation warp (augmentation.warp_batch):
    # "auto" = the fused Pallas kernel on TPU, the map_coordinates
    # reference elsewhere; only consulted when the engine holds an aug plan
    warp_impl: str = "auto"
    use_kernel_agg: bool = False
    # route Alg. 3 rescheduling through the one-launch Pallas greedy pass
    # (kernels.kld_greedy_picks) instead of the XLA masked-argmin scan;
    # identical mediator lists (property-tested), O(1) kernel launches per
    # pass -- the Mosaic path for 1e5+-client reschedules on TPU
    reschedule_kernel: bool = False
    reschedule_every_round: bool = False
    # true tensor-parallel row compute (§8 TP mode): "auto" turns it on
    # when the mesh has a model axis AND the backend's partitioner can
    # handle lax.scan under partial-auto shard_map (TPU/GPU); True forces
    # it (raising on CPU); False keeps the gather->replicated-compute
    # oracle everywhere. model=1 meshes always resolve to the oracle.
    tp_rows: bool | str = "auto"
    # LoRA adapter exchange: rank of the per-tensor adapter mapping table
    # built from model.param_specs() (models/lora.py). None = full-delta
    # exchange (historical behavior); 0 = fully frozen backbone; at
    # rank >= models.lora.full_rank(specs) every entry degenerates to
    # dense and the trajectory is bitwise the full-delta oracle's.
    lora_rank: int | None = None
    lora_alpha: float | None = None         # merge scale; None = rank (1.0)
    donate_params: bool = True
    # floor for the padded mediator count (rounded up to the mesh size);
    # fixes M across reschedules so the round executable is jitted once
    pad_mediators_to: int | None = None
    seed: int = 0

    def __post_init__(self):
        if self.schedule not in ("kld", "random"):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.aggregate not in ("delta", "weights"):
            raise ValueError(f"unknown aggregate {self.aggregate!r}")
        if self.store not in POLICIES:
            raise ValueError(f"unknown client-store policy {self.store!r}; "
                             f"expected one of {POLICIES}")
        if self.store_exchange not in EXCHANGES:
            raise ValueError(f"unknown store_exchange {self.store_exchange!r}; "
                             f"expected one of {EXCHANGES}")
        if self.row_exec not in ("vmap", "map"):
            raise ValueError(f"unknown row_exec {self.row_exec!r}")
        if self.store_prefetch_depth < 1:
            raise ValueError("store_prefetch_depth must be >= 1")
        if self.store_lru_rows is not None and self.store_lru_rows < 0:
            raise ValueError("store_lru_rows must be >= 0")
        if self.warp_impl not in augmentation.WARP_IMPLS:
            raise ValueError(f"unknown warp_impl {self.warp_impl!r}; "
                             f"expected one of {augmentation.WARP_IMPLS}")
        if self.aggregate == "weights" and self.gamma != 1:
            raise ValueError("weight aggregation implies gamma=1 (FedAvg)")
        if self.pad_mediators_to is not None and self.pad_mediators_to < 1:
            raise ValueError("pad_mediators_to must be >= 1")
        if self.tp_rows not in (True, False, "auto"):
            raise ValueError(f"tp_rows must be True, False or 'auto', "
                             f"got {self.tp_rows!r}")
        if self.lora_rank is not None and self.lora_rank < 0:
            raise ValueError("lora_rank must be >= 0")
        if self.lora_alpha is not None and self.lora_rank is None:
            raise ValueError("lora_alpha requires lora_rank")

    @classmethod
    def astraea(cls, *, clients_per_round: int, gamma: int, local: LocalSpec,
                mediator_epochs: int = 1, **kw) -> "EngineConfig":
        return cls(clients_per_round=clients_per_round, gamma=gamma,
                   local=local, mediator_epochs=mediator_epochs,
                   schedule="kld", aggregate="delta", **kw)

    @classmethod
    def fedavg(cls, *, clients_per_round: int, local: LocalSpec,
               **kw) -> "EngineConfig":
        """FedAvg == one client per mediator, fresh random singleton schedule
        every round, full-weight aggregation."""
        kw.setdefault("reschedule_every_round", True)
        return cls(clients_per_round=clients_per_round, gamma=1, local=local,
                   schedule="random", aggregate="weights", **kw)


class FLRoundEngine:
    """Device-resident federated round executor (see module docstring)."""

    def __init__(self, model: Model, opt: Optimizer, data: FederatedDataset,
                 cfg: EngineConfig, *, mesh=None,
                 loss_fn: Callable | None = None,
                 aug_plan: np.ndarray | None = None,
                 adaptive_aug_alpha: float | None = None,
                 telemetry=None):
        self.model, self.opt, self.data, self.cfg = model, opt, data, cfg
        # host-side observability handle (obs/): spans + metrics around --
        # never inside -- the jitted round, so telemetry on/off is bitwise
        # identical and adds zero traces (tests/test_telemetry.py)
        self.telemetry = as_telemetry(telemetry)
        self.mesh = mesh if mesh is not None else default_fl_mesh()
        self._msize = int(self.mesh.shape["mediator"])
        self._model_size = model_axis_size(self.mesh)
        if adaptive_aug_alpha is not None and aug_plan is None:
            raise ValueError("adaptive_aug_alpha requires an initial aug_plan "
                             "(the in-round hook must be installed at trace "
                             "time)")
        self._adaptive_alpha = adaptive_aug_alpha

        capacity = min(cfg.clients_per_round, data.num_clients)
        if hasattr(data, "client_images"):
            sizes = [x.shape[0] for x in data.client_images]
            pad = _pad_multiple(max(sizes), cfg.local.batch_size)
            # packed ONCE into the placement-policy store (replicated
            # buffers, client-sharded buffers, host RAM, or a disk/mmap
            # spill tier -- see core/client_store.py). With online
            # augmentation the store holds the RAW clients: the warped
            # copies only ever exist inside the round program.
            xs, ys, mask = data.padded(pad)
            self.store = build_client_store(
                cfg.store, xs, ys, mask, self.mesh, capacity=capacity,
                exchange=cfg.store_exchange,
                prefetch_depth=cfg.store_prefetch_depth,
                lru_rows=cfg.store_lru_rows)
        else:
            # streaming federation (row-source protocol, e.g.
            # data.synthetic.StreamingFederation): clients are fetched /
            # synthesized on demand by the streaming stores -- the
            # federation is never materialized, so only the policies with
            # O(c) residency can serve it
            if cfg.store not in ("host", "spilled"):
                raise ValueError(
                    f"streaming federations require the 'host' or 'spilled' "
                    f"client store, got {cfg.store!r}")
            if data.pad % cfg.local.batch_size:
                raise ValueError(
                    f"streaming federation pad {data.pad} is not a multiple "
                    f"of batch_size {cfg.local.batch_size}")
            self.store = build_client_store(
                cfg.store, mesh=self.mesh, capacity=capacity, source=data,
                prefetch_depth=cfg.store_prefetch_depth,
                lru_rows=cfg.store_lru_rows)
        self.store.telemetry = self.telemetry
        self._raw_counts = data.client_counts()
        self._counts = self._raw_counts
        self._rng = np.random.default_rng(cfg.seed)
        # pre-drawn future selections, oldest first (ensure_schedule keeps
        # this filled to the store's prefetch depth)
        self._pending_sels: deque = deque()

        # ---- params: model-axis sharded at rest, replicated otherwise ----
        # On a 2-D mesh each device holds 1/model of every rule-table-
        # sharded leaf (§8); on the 1-D mesh (or without param specs) the
        # params are committed replicated up front -- round outputs carry
        # the same sharding either way, so the second round never
        # cache-misses the executable.
        replicated = replicated_sharding(self.mesh)
        self._param_shardings = None
        if self._model_size > 1 and model.param_specs is not None:
            self._param_shardings = param_shardings(
                model.param_specs(), self.mesh, model_only_rules())
        placement = self._param_shardings if self._param_shardings is not None \
            else replicated
        self.params = jax.device_put(model.init(jax.random.PRNGKey(cfg.seed)),
                                     placement)
        # report the model axis the params are ACTUALLY sharded over: a
        # spec-less model stays fully replicated even on a 2-D mesh
        self.store.note_param_residency(
            _per_device_param_bytes(self.params),
            self._model_size if self._param_shardings is not None else 1)
        self.comm = CommMeter(count_params(self.params))

        # ---- §8 TP mode: shard the row compute over the model axis ----
        self._tp_rows = self._resolve_tp_rows()

        # ---- LoRA adapter exchange (models/lora.py mapping table) ----
        # With a mapping installed, self.params becomes the FROZEN
        # backbone: the round's donated arg-0 state is the flat adapter
        # dict, the backbone + the seeded frozen-A bases ride as trailing
        # value-swap operands (the aug_args pattern), and only adapter
        # bytes are charged on the WAN ledger.
        self._lora_mapping = None
        self._lora_a = None
        self.adapters = None
        self._merge_fn = None
        self.num_merge_traces = 0           # merged_params (re)compilations
        if cfg.lora_rank is not None:
            if model.param_specs is None:
                raise ValueError(
                    "lora_rank requires a model with param_specs (the "
                    "adapter mapping table is built from its LogicalParam "
                    "tree)")
            mapping = lora_lib.build_mapping(model.param_specs(),
                                             cfg.lora_rank, cfg.lora_alpha)
            self._lora_mapping = mapping
            a_key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                       lora_lib.A_SALT)
            self._lora_a = jax.device_put(
                lora_lib.init_adapter_A(a_key, mapping), replicated)
            self.adapters = jax.device_put(
                lora_lib.init_adapter_state(mapping, self.params), replicated)
            # every model-exchange leg now ships the adapter payload; the
            # meter books it under wan_adapter_bytes and keeps the
            # full-size counterfactual for the scrapeable reduction ratio
            self.comm.adapter_payload_bytes = lora_lib.exchange_nbytes(
                mapping, self.comm.bytes_per_param)

            def _merge(backbone, a_tree, state):
                self.num_merge_traces += 1      # python: trace-time only
                return lora_lib.merge_params(backbone, a_tree, state,
                                             mapping)

            self._merge_fn = jax.jit(_merge)

        # ---- online-rebalancing plan (Alg. 2, device-resident mode) ----
        self._aug_plan = None
        self.last_plan: np.ndarray | None = None
        if aug_plan is not None:
            plan_np = np.asarray(aug_plan)
            if plan_np.shape != (data.num_classes,):
                raise ValueError(
                    f"aug_plan shape {plan_np.shape} != ({data.num_classes},)")
            self._install_plan(plan_np)
            # the plan broadcast is WAN traffic: (num_classes,) int32 down
            # to every client, once at initialization (adaptive refreshes
            # re-broadcast to each round's cohort in _pack_schedule)
            self.comm.plan_broadcast(plan_np.size, data.num_clients)
        self.history: list[dict] = []
        self.last_schedule_stats: dict | None = None
        # the current schedule's client groups (schedule order) -- the
        # client-level straggler model derives durations from membership
        self.last_groups: list[list[int]] | None = None
        self.num_schedule_packs = 0             # host packing events (bench)
        self.num_round_traces = 0               # round_fn (re)compilations
        # one entry per (re)trace with its *reason* -- "initial" for each
        # entry point's first compile, "retrace" for anything after; the
        # metrics registry surfaces the retrace count as engine health
        self.trace_log: list[dict] = []
        self._schedule: tuple | None = None
        self._round = 0
        self._wave_fns: dict[int, Callable] = {}    # width -> sliced wave_fn
        self._round_fn = self._build_round_fn(loss_fn)

    # ------------------------------------------------------------------
    # round program
    # ------------------------------------------------------------------
    def _install_plan(self, plan_np: np.ndarray) -> None:
        """(Re)place the Alg. 2 plan operand and rescale the Alg. 3 counts.

        The plan is a true argument of the jitted round (same shape/dtype/
        sharding every time), so swapping its *value* -- the adaptive
        per-reschedule path -- reuses the one compiled executable."""
        plan_np = np.asarray(plan_np)
        self.last_plan = plan_np
        self._aug_plan = jax.device_put(jnp.asarray(plan_np, jnp.int32),
                                        replicated_sharding(self.mesh))
        # Alg. 3 packs mediators by the histograms clients WILL train on:
        # the expected post-augmentation counts (the materialized mode sees
        # the same thing through its inflated client data)
        self._counts = self._raw_counts * (1.0 + plan_np.astype(np.float64))

    def _resolve_tp_rows(self) -> bool:
        """Resolve ``cfg.tp_rows`` against the mesh and backend.

        TP row compute only exists when the params actually shard over a
        model axis; ``"auto"`` additionally requires a TPU/GPU backend
        because the XLA-CPU partitioner crashes on ``lax.scan`` under
        partial-auto shard_map (§8) -- CPU always falls back to the
        gather->replicated-compute oracle.  An explicit ``True`` on an
        unsupported backend raises instead of silently downgrading."""
        mode = self.cfg.tp_rows
        if mode is False or self._model_size <= 1 \
                or self._param_shardings is None:
            return False
        supported = jax.default_backend() in ("tpu", "gpu")
        if mode == "auto":
            return supported
        if not supported:
            raise ValueError(
                f"tp_rows=True needs a TPU/GPU backend, got "
                f"{jax.default_backend()!r}: the XLA-CPU partitioner "
                "crashes on lax.scan under partial-auto shard_map (§8). "
                "Use tp_rows='auto' to fall back to the gather oracle.")
        return True

    def aug_args(self) -> tuple:
        """The round executable's trailing Alg. 2 operand (empty if the
        engine holds no plan). Callers of ``wave_fn`` append this."""
        return (self._aug_plan,) if self._aug_plan is not None else ()

    def lora_args(self) -> tuple:
        """The round executable's trailing LoRA operands: the frozen
        backbone and the seeded A bases (empty without a mapping).  Pure
        value swaps -- same shapes/dtypes/shardings every round, so
        reschedules and backbone refreshes never re-trace."""
        if self._lora_mapping is None:
            return ()
        return (self.params, self._lora_a)

    def extra_args(self) -> tuple:
        """All trailing value-swap operands of the round/wave executables
        (Alg. 2 plan first, then the LoRA backbone + A)."""
        return self.aug_args() + self.lora_args()

    @property
    def server_state(self):
        """The trainable surface the round folds into: the flat adapter
        dict under LoRA, the full params otherwise."""
        if self._lora_mapping is not None:
            return self.adapters
        return self.params

    @server_state.setter
    def server_state(self, value):
        if self._lora_mapping is not None:
            self.adapters = value
        else:
            self.params = value

    def merged_params(self):
        """Evaluation-ready weights: the jitted merge-to-backbone value
        swap under LoRA (one trace for the engine's lifetime --
        ``num_merge_traces``), the params themselves otherwise."""
        if self._lora_mapping is None:
            return self.params
        return self._merge_fn(self.params, self._lora_a, self.adapters)

    def replicate_params(self, params: PyTree) -> PyTree:
        """Gather model-axis-sharded params to model-replicated (inside a
        jitted program). Identity on a 1-D mesh -- the gather/reshard pair
        moves exact bytes, which is what keeps 2-D trajectories bitwise."""
        if self._param_shardings is None:
            return params
        return jax.lax.with_sharding_constraint(
            params, replicated_sharding(self.mesh))

    def shard_params(self, params: PyTree) -> PyTree:
        """Reshard params back onto the model axis (inverse of
        ``replicate_params``; identity on a 1-D mesh)."""
        if self._param_shardings is None:
            return params
        return jax.lax.with_sharding_constraint(params, self._param_shardings)

    def _note_trace(self, fn: str) -> None:
        """Python side effect inside the jitted bodies: runs at TRACE time
        only, counting (re)compilations and recording why -- the first
        trace per entry point is expected ("initial"); anything after is
        an unexpected "retrace" (a shape/dtype/sharding drift)."""
        self.num_round_traces += 1
        first = not any(t["fn"] == fn for t in self.trace_log)
        self.trace_log.append({"fn": fn, "round": self._round,
                               "trace_index": self.num_round_traces,
                               "reason": "initial" if first else "retrace"})

    def _build_round_fn(self, loss_fn):
        cfg, store = self.cfg, self.store
        parallel_clients = cfg.aggregate == "weights"
        lora_on = self._lora_mapping is not None

        def _updates_for(model):
            if parallel_clients:
                return make_client_update(model, self.opt, cfg.local,
                                          loss_fn=loss_fn)
            return make_mediator_update(model, self.opt, cfg.local,
                                        cfg.mediator_epochs, loss_fn=loss_fn)

        # without LoRA the update program is fixed at build time; with it,
        # the per-row program trains the ADAPTER tree through a model whose
        # apply merges into the traced backbone, so the update closures are
        # built at trace time (once -- the round is traced once)
        base_update = None if lora_on else _updates_for(self.model)
        P_med = P("mediator")
        use_aug = self._aug_plan is not None
        n_aug = 1 if use_aug else 0

        def _rows(fn, params, *batched):
            if cfg.row_exec == "map":
                return jax.lax.map(lambda args: fn(params, *args), batched)
            return jax.vmap(fn, in_axes=(None,) + (0,) * len(batched))(
                params, *batched)

        def _aug_one(key, x, y, m, aplan):
            # the augmentation stream forks off the row's round key with a
            # salt, leaving the training stream (split from the same key
            # inside the update) untouched
            return augmentation.online_augment_batch(
                jax.random.fold_in(key, augmentation.AUG_SALT), x, y, m,
                aplan, impl=cfg.warp_impl)

        def _train(state, data, plan, slot, keys, *extra):
            # plan/slot/keys arrive as this device's (M_local, ...) shards;
            # the store resolves them against its resident client buffers.
            # extra carries the value-swap operands: the replicated
            # (num_classes,) Alg. 2 plan when augmenting, then the LoRA
            # (backbone, a_tree) pair when a mapping is installed -- in
            # which case arg-0 `state` is the flat adapter dict and the
            # update closures train it through the merged-apply model.
            aug = extra[:n_aug]
            if lora_on:
                backbone, a_tree = extra[n_aug:]
                mapping = self._lora_mapping
                merged = dc_replace(
                    self.model,
                    apply=lambda tp, x, **kw: self.model.apply(
                        lora_lib.merge_params(backbone, a_tree, tp, mapping),
                        x, **kw))
                update = _updates_for(merged)
            else:
                update = base_update
            xs, ys, ms_raw = store.slot_data(data, plan)
            if parallel_clients:
                ms = ms_raw[:, 0] * slot[:, :1]
                row_fn = update
                weights = ms.sum(axis=1)
                if use_aug:
                    (aplan,) = aug
                    def row_fn(p, x, y, m, k):           # noqa: F811
                        ax, ay = _aug_one(k, x, y, m, aplan)
                        return update(p, ax, ay, m, k)
                    # Eq. 6 over the expected post-augmentation sizes
                    weights = (ms * (1.0 + aplan.astype(jnp.float32)[ys[:, 0]])
                               ).sum(axis=1)
                outs = _rows(row_fn, state, xs[:, 0], ys[:, 0], ms, keys)
                return outs, weights
            ms = ms_raw * slot[..., None]
            row_fn = update
            weights = ms.sum(axis=(1, 2))
            if use_aug:
                (aplan,) = aug
                def row_fn(p, xr, yr, mr, k):            # noqa: F811
                    aks = jax.random.split(
                        jax.random.fold_in(k, augmentation.AUG_SALT),
                        xr.shape[0])
                    ax, ay = jax.vmap(
                        lambda kk, x, y, m: augmentation.online_augment_batch(
                            kk, x, y, m, aplan, impl=cfg.warp_impl)
                    )(aks, xr, yr, mr)
                    return update(p, ax, ay, mr, k)
                weights = (ms * (1.0 + aplan.astype(jnp.float32)[ys])
                           ).sum(axis=(1, 2))
            outs = _rows(row_fn, state, xs, ys, ms, keys)
            return outs, weights

        aug_specs = (P(),) if use_aug else ()
        # LoRA trailing operands: backbone + frozen A, replicated over the
        # mediator axis (under TP rows the backbone's model sharding rides
        # the compiler-auto model axis; under the gather oracle it arrives
        # model-replicated -- round_fn gathers it first)
        lora_specs = (P(), P()) if lora_on else ()
        # §8: with TP rows only the mediator axis is manual -- the model
        # axis stays compiler-auto so the row forward/backward runs truly
        # tensor-parallel (never materializing the full replica); otherwise
        # every mesh axis is manual (identical replicated-compute columns,
        # and partial-auto would trip the XLA-CPU scan crash)
        manual = ("mediator",) if self._tp_rows \
            else tuple(self.mesh.axis_names)
        train = mediator_shard_map(
            _train, self.mesh,
            in_specs=(P(), store.data_specs, store.plan_specs,
                      P_med, P_med) + aug_specs + lora_specs,
            out_specs=(P_med, P_med),
            manual_axes=manual)

        def trained_rows(state, data, plan, unperm, slot, keys, *extra):
            stacked, weights = train(state, data, plan, slot, keys, *extra)
            if store.permutes_rows:             # undo locality placement
                stacked = jax.tree.map(lambda a: a[unperm], stacked)
                weights = weights[unperm]
            # replicate the (M, ...) stack before Eq. 6 so the reduction
            # order (and hence the result, bitwise) is mesh-independent
            rep = replicated_sharding(self.mesh)
            stacked = jax.lax.with_sharding_constraint(stacked, rep)
            weights = jax.lax.with_sharding_constraint(weights, rep)
            return stacked, weights

        def _prep(state, extra):
            # the pre-shard_map gathers of the gather oracle: replicate
            # the model-sharded weights (arg-0 params, or the LoRA
            # backbone operand) so the fully-manual region sees them
            # whole.  Under TP rows both stay model-sharded -- that is
            # the point -- and on a 1-D mesh both are identities.
            if self._tp_rows:
                return state, extra
            if lora_on:
                backbone, a_tree = extra[n_aug:]
                return state, extra[:n_aug] + (
                    self.replicate_params(backbone), a_tree)
            return self.replicate_params(state), extra

        def round_fn(state, data, plan, unperm, slot, keys, *extra):
            self._note_trace("round_fn")        # python: counts (re)traces
            state, extra = _prep(state, extra)          # §8: model gather
            stacked, weights = trained_rows(state, data, plan, unperm, slot,
                                            keys, *extra)
            agg = self._aggregate(stacked, weights)
            return self._fold(state, agg)

        def wave_fn(state, data, plan, unperm, slot, keys, *extra):
            # the wave-partitioned entry point (core/async_engine.py): the
            # SAME full padded-M program, stopping before aggregation. The
            # caller zeroes the slot rows of mediators outside the wave
            # (exact no-ops, like dummy mediators), so one trace serves
            # every wave of every reschedule. No donation: the dispatch
            # snapshot state is shared by all waves of a round.
            self._note_trace("wave_fn")         # python: counts (re)traces
            state, extra = _prep(state, extra)          # §8: model gather
            return trained_rows(state, data, plan, unperm, slot, keys, *extra)

        self.wave_fn = jax.jit(wave_fn)

        def make_sliced_wave_fn(m_rows: int):
            # the overlapped dispatch path (wave_fn_for): the SAME row
            # program over an (m_rows, ...) slice of the packed schedule
            # instead of the masked full padded-M stack -- a W-wave round
            # then costs ~1x the sync round's row compute instead of Wx.
            # Each width is its own entry point with its own "initial"
            # trace, so the per-shape zero-retrace contract is auditable
            # in trace_log.
            tag = f"wave_fn[{m_rows}]"

            def sliced(state, data, plan, unperm, slot, keys, *extra):
                self._note_trace(tag)       # python: counts (re)traces
                state, extra = _prep(state, extra)      # §8: model gather
                return trained_rows(state, data, plan, unperm, slot, keys,
                                    *extra)

            return jax.jit(sliced)

        self._make_wave_fn = make_sliced_wave_fn
        donate = (0,) if cfg.donate_params else ()
        return jax.jit(round_fn, donate_argnums=donate)

    def wave_fn_for(self, m_rows: int) -> Callable:
        """The sliced wave executable over ``m_rows`` schedule rows.

        ``wave_fn`` restricted to one wave: plan/slot/keys arrive as
        ``(m_rows, ...)`` row slices of the packed schedule (``m_rows`` a
        multiple of the mediator mesh size, identity ``unperm``); the
        data operands are the store's full resident buffers, unchanged.
        One executable is compiled per distinct width and cached for the
        engine's lifetime under trace tag ``wave_fn[m_rows]``.

        Row-permuting stores (``sharded``) route each row's gathers by
        its device position in the FULL schedule, so their rows cannot be
        re-sliced without replanning -- callers must fall back to the
        masked full-M ``wave_fn`` (the async engine does).
        """
        if self.store.permutes_rows:
            raise ValueError(
                f"the {self.store.policy!r} store routes gathers by row "
                "position; sliced wave executables need a non-permuting "
                "store (use the masked wave_fn instead)")
        if m_rows < 1 or m_rows % self._msize:
            raise ValueError(
                f"wave width {m_rows} must be a positive multiple of the "
                f"mediator mesh size {self._msize}")
        fn = self._wave_fns.get(m_rows)
        if fn is None:
            fn = self._make_wave_fn(m_rows)
            self._wave_fns[m_rows] = fn
        return fn

    def _fold(self, state, agg) -> PyTree:
        """Fold the Eq. 6 aggregate into the server state -- the shared
        tail of the sync round and the async commit, so S=0 async stays
        bitwise equal to sync by construction.

        Without LoRA this is the historical params fold: take the
        aggregate outright under weight aggregation, else add the delta to
        the (model-replicated) params, and reshard onto the model axis.
        Under LoRA the state is the replicated flat adapter dict and the
        fold is sharding-free."""
        if self._lora_mapping is not None:
            if self.cfg.aggregate == "weights":
                return agg
            return jax.tree.map(lambda s, d: s + d, state, agg)
        if self.cfg.aggregate == "weights":
            return self.shard_params(agg)
        return self.shard_params(
            jax.tree.map(lambda p, d: p + d, self.replicate_params(state),
                         agg))

    def _aggregate(self, stacked: PyTree, weights: jax.Array) -> PyTree:
        """Eq. 6 over the stacked (M, ...) mediator results."""
        return eq6_aggregate(stacked, weights, self.mesh,
                             use_kernel_agg=self.cfg.use_kernel_agg)

    # ------------------------------------------------------------------
    # scheduling (host side: tiny integer work, no sample movement)
    # ------------------------------------------------------------------
    def _groups_for(self, sel: np.ndarray) -> list[list[int]]:
        cfg = self.cfg
        if cfg.schedule == "kld":
            meds = scheduling.reschedule(self._counts[sel], cfg.gamma,
                                         use_kernel=cfg.reschedule_kernel)
            self.last_schedule_stats = scheduling.schedule_stats(meds)
            return [[int(sel[i]) for i in m.clients] for m in meds]
        if cfg.schedule == "random":
            if cfg.gamma == 1:      # FedAvg: selection order, one client each
                self.last_schedule_stats = None
                return [[int(k)] for k in sel]
            meds = scheduling.random_schedule(len(sel), cfg.gamma,
                                              self._counts[sel],
                                              seed=cfg.seed + self._round)
            self.last_schedule_stats = scheduling.schedule_stats(meds)
            return [[int(sel[i]) for i in m.clients] for m in meds]
        raise ValueError(f"unknown schedule {cfg.schedule!r}")

    def _pack_schedule(self, sel: np.ndarray) -> tuple:
        """Schedule -> store-routed gather plan.

        Packs the client groups into padded ``(M_pad, gamma)`` rows (rows
        assigned by the store's placement pass), remaps the gather through
        the store, and precomputes ``unperm`` -- the row order that puts
        stacked outputs back in schedule order before aggregation (real
        mediators first, dummies last), which is what keeps every
        placement bit-identical to the replicated path.
        """
        tel = self.telemetry
        if self._adaptive_alpha is not None:
            # per-round adaptive rebalancing: recompute the Alg. 2 plan
            # from the *selected cohort's* label histograms (the drifted
            # view of the federation this round trains on), re-broadcast
            # the tiny array to the cohort, and let Alg. 3 below pack by
            # the refreshed expected post-augmentation counts. The plan is
            # a round operand, so no re-trace happens (asserted in tests).
            with tel.span("plan_refresh", cohort=len(sel)):
                plan_np = augmentation.augmentation_plan(
                    self._raw_counts[sel].sum(axis=0), self._adaptive_alpha)
                self._install_plan(plan_np)
                self.comm.plan_broadcast(plan_np.size, len(sel))
        with tel.span("reschedule", cohort=len(sel),
                      schedule=self.cfg.schedule) as rsp:
            groups = self._groups_for(sel)
            if self.last_schedule_stats:
                rsp.set(kld_mean=self.last_schedule_stats.get("kld_mean"),
                        num_mediators=len(groups))
        self.last_groups = groups
        m_real = len(groups)
        m_pad = self.cfg.pad_mediators_to or m_real
        if m_pad < m_real:
            raise ValueError(
                f"pad_mediators_to={m_pad} smaller than the schedule "
                f"({m_real} mediators)")
        m_pad = _pad_multiple(m_pad, self._msize)
        with tel.span("pack", m_real=m_real, m_pad=m_pad,
                      policy=self.store.policy) as psp:
            row_to_group = self.store.place(groups, m_pad)
            idx = np.zeros((m_pad, self.cfg.gamma), np.int32)
            slot = np.zeros((m_pad, self.cfg.gamma), np.float32)
            row_of = np.zeros(m_real, np.int64)
            for r, g in enumerate(row_to_group):
                if g < 0:
                    continue
                row_of[g] = r
                for ci, cid in enumerate(groups[g]):
                    idx[r, ci] = cid
                    slot[r, ci] = 1.0
            dummy_rows = np.flatnonzero(row_to_group < 0)
            unperm = np.concatenate([row_of, dummy_rows]).astype(np.int32)
            with tel.span("store_stream", policy=self.store.policy) as ssp:
                data_args, plan_args = self.store.plan(idx, slot)
                ssp.set(bytes=self.store.last_stream_bytes)
                ssp.sync_on(data_args)
            if self.store.last_stream_bytes:
                # host->device streaming is pod-side traffic: intra-pod
                # ledger only, so the WAN bytes stay invariant to placement
                self.comm.store_stream(self.store.last_stream_bytes)
            if getattr(self.store, "last_placement_stats", None):
                # store placement telemetry rides along under a store_
                # namespace: a raw merge once let colliding keys (e.g. a
                # future store "num_mediators") silently clobber the
                # scheduler's numbers
                base = self.last_schedule_stats or {}
                prefixed = {f"store_{k}": v for k, v in
                            self.store.last_placement_stats.items()}
                overlap = base.keys() & prefixed.keys()
                assert not overlap, \
                    f"schedule/store stats key collision: {sorted(overlap)}"
                self.last_schedule_stats = {**base, **prefixed}
            psp.set(stream_bytes=self.store.last_stream_bytes)
        self.num_schedule_packs += 1
        return (data_args, plan_args, jnp.asarray(unperm),
                jnp.asarray(slot), row_to_group, m_real)

    def _round_keys(self, row_to_group: np.ndarray, m_real: int,
                    round_idx: int | None = None) -> jax.Array:
        base = jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed + 1),
            self._round if round_idx is None else round_idx)
        keys = jax.random.split(base, m_real)   # split at the REAL count
        take = np.where(row_to_group >= 0, row_to_group, 0)
        rows = jnp.asarray(keys)[jnp.asarray(take)]
        real = jnp.asarray(row_to_group >= 0)   # dummy rows: any key no-ops
        return jnp.where(real[:, None], rows, jnp.zeros_like(rows))

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def ensure_schedule(self) -> tuple:
        """(Re)pack the gather schedule if this round needs one.

        With a prefetch-capable store (``spilled``) and per-round
        rescheduling, the next rounds' selections are pre-drawn here --
        up to the store's ``prefetch_depth`` ahead -- and staged in the
        background, so the spill-tier reads overlap this round's device
        compute. The rng draws happen in the same order as the eager
        path (round r's selection is always the (r+1)-th ``choice``
        call; depth only changes how early the calls are issued), so
        trajectories are bitwise unchanged at any depth."""
        cfg = self.cfg
        c = min(cfg.clients_per_round, self.data.num_clients)
        if cfg.reschedule_every_round or self._schedule is None:
            if self._pending_sels:
                sel = self._pending_sels.popleft()
            else:
                sel = self._rng.choice(self.data.num_clients, size=c,
                                       replace=False)
            self._schedule = self._pack_schedule(sel)
            if cfg.reschedule_every_round and hasattr(self.store, "prefetch"):
                depth = max(1, int(getattr(self.store, "prefetch_depth", 1)))
                while len(self._pending_sels) < depth:
                    nxt = self._rng.choice(self.data.num_clients, size=c,
                                           replace=False)
                    self._pending_sels.append(nxt)
                    self.store.prefetch(nxt)
        return self._schedule

    def run_round(self) -> None:
        cfg, tel = self.cfg, self.telemetry
        c = min(cfg.clients_per_round, self.data.num_clients)
        wan0 = self.comm.total_bytes
        with tel.span("round", round=self._round, cohort=c,
                      schedule=cfg.schedule, policy=cfg.store) as rsp:
            data_args, plan_args, unperm, slot, row_to_group, m_real = \
                self.ensure_schedule()
            keys = self._round_keys(row_to_group, m_real)
            with tel.span("aggregate", mediators=m_real) as asp:
                self.server_state = self._round_fn(self.server_state,
                                                   data_args, plan_args,
                                                   unperm, slot, keys,
                                                   *self.extra_args())
                asp.sync_on(self.server_state)
            if cfg.aggregate == "weights":
                self.comm.fedavg_round(c)
            else:
                self.comm.astraea_round(c, cfg.gamma, cfg.mediator_epochs)
            if self._model_size > 1 and (self._lora_mapping is None
                                         or not self._tp_rows):
                # intra-pod ledger only: the per-round model-axis param
                # gather must never pollute the bytes behind the 82% claim.
                # TP-rows + LoRA is the one mode with no gather at all (the
                # backbone stays sharded and the adapters are replicated);
                # non-LoRA gathers either in-round or at the _fold add, and
                # gather-mode LoRA gathers the backbone operand.
                self.comm.model_axis_round(self._msize * self._model_size,
                                           self._model_size)
            if self.store.exchange_bytes_per_round:
                # the sharded serve exchange executes with every round
                # program; mark the charge on the timeline too
                self.comm.store_exchange(self.store.exchange_bytes_per_round)
                tel.instant("store_exchange",
                            bytes=self.store.exchange_bytes_per_round)
            self.comm.end_round()
            self._round += 1
            rsp.set(wan_bytes=self.comm.total_bytes - wan0,
                    traces=self.num_round_traces)
        tel.observe_round(self, duration_s=rsp.duration_s)

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        for _ in range(rounds):
            self.run_round()
            if self._round % eval_every == 0 or self._round == rounds:
                m = evaluate(self.model, self.merged_params(),
                             self.data.test_images, self.data.test_labels)
                m.update(round=self._round, traffic_mb=self.comm.megabytes)
                if self.last_schedule_stats and \
                        "kld_mean" in self.last_schedule_stats:
                    m["mediator_kld_mean"] = self.last_schedule_stats["kld_mean"]
                self.history.append(m)
        return self.history
