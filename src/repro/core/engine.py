"""Sharded FL round engine: pack once, gather on device, shard mediators.

One engine drives both algorithms in this repo:

* **Astraea** (paper Alg. 1/3): KLD-greedy mediator schedule, up to ``gamma``
  clients per mediator trained *sequentially* for ``E_m`` mediator epochs,
  FedAvg aggregation (Eq. 6) over the mediator weight *deltas*.
* **FedAvg** (the baseline): exactly the ``gamma=1`` + random-singleton
  schedule + full-weight aggregation configuration of the same engine --
  every "mediator" holds one client training from the global weights.

What makes it an engine rather than a trainer loop:

1. **Pack once.** The padded per-client arrays ``(K, pad, ...)`` are moved
   to device at construction. A schedule is a tiny ``(M, gamma)`` int32
   gather index plus a 0/1 slot mask; ``run_round`` never rebuilds host
   numpy buffers (the old trainers re-packed ``(M, gamma, pad, ...)`` on
   the host every round). Gathering ``x_all[idx]`` happens on device
   inside the jitted round. Slot-mask zeros make empty client slots exact
   no-ops (masked loss is 0 => zero grads => zero Adam updates), so a
   dummy slot may harmlessly gather client 0's data.
2. **Mediator sharding.** Mediators are distributed over the ``mediator``
   axis of a device mesh via shard_map; ``M`` is padded up to the mesh
   size with zero-weight dummy mediators (also exact no-ops). On a 1-device
   CPU mesh this degrades to plain vmap semantics bit-for-bit.
3. **Donated params.** The round executable receives the parameter buffer
   with ``donate_argnums`` so the server-side update is in-place on
   accelerators.
4. **Kernel aggregation.** ``use_kernel_agg`` routes Eq. 6 through the
   ``fedavg_agg`` Pallas kernel (interpret-mode on CPU, Mosaic on TPU);
   default is the pure-jnp ``weighted_average`` (same math, XLA-fused).

RNG note: per-round keys are split at the *real* mediator count before
dummy-mediator padding (``jax.random.split`` is not prefix-stable), so the
trajectory is independent of the mesh size and bit-identical to the
pre-engine trainers on a single device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import scheduling
from repro.core.comm import CommMeter
from repro.core.fl import (LocalSpec, evaluate, make_client_update,
                           weighted_average)
from repro.core.mediator import make_mediator_update
from repro.data.federated import FederatedDataset
from repro.launch.compat import shard_map
from repro.launch.mesh import make_mediator_mesh
from repro.models.cnn import Model, count_params
from repro.optim.optimizers import Optimizer

PyTree = Any


def _pad_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass(frozen=True)
class EngineConfig:
    """Static round configuration. ``astraea()``/``fedavg()`` build the two
    canonical settings; everything between them is a valid ablation."""
    clients_per_round: int                  # c
    gamma: int                              # max clients per mediator
    local: LocalSpec                        # B, E
    mediator_epochs: int = 1                # E_m
    schedule: str = "kld"                   # "kld" (Alg. 3) | "random"
    aggregate: str = "delta"                # "delta" (Astraea) | "weights" (FedAvg)
    use_kernel_agg: bool = False
    reschedule_every_round: bool = False
    donate_params: bool = True
    # floor for the padded mediator count (rounded up to the mesh size);
    # fixes M across reschedules so the round executable is jitted once
    pad_mediators_to: int | None = None
    seed: int = 0

    def __post_init__(self):
        if self.schedule not in ("kld", "random"):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.aggregate not in ("delta", "weights"):
            raise ValueError(f"unknown aggregate {self.aggregate!r}")
        if self.aggregate == "weights" and self.gamma != 1:
            raise ValueError("weight aggregation implies gamma=1 (FedAvg)")
        if self.pad_mediators_to is not None and self.pad_mediators_to < 1:
            raise ValueError("pad_mediators_to must be >= 1")

    @classmethod
    def astraea(cls, *, clients_per_round: int, gamma: int, local: LocalSpec,
                mediator_epochs: int = 1, **kw) -> "EngineConfig":
        return cls(clients_per_round=clients_per_round, gamma=gamma,
                   local=local, mediator_epochs=mediator_epochs,
                   schedule="kld", aggregate="delta", **kw)

    @classmethod
    def fedavg(cls, *, clients_per_round: int, local: LocalSpec,
               **kw) -> "EngineConfig":
        """FedAvg == one client per mediator, fresh random singleton schedule
        every round, full-weight aggregation."""
        kw.setdefault("reschedule_every_round", True)
        return cls(clients_per_round=clients_per_round, gamma=1, local=local,
                   schedule="random", aggregate="weights", **kw)


class FLRoundEngine:
    """Device-resident federated round executor (see module docstring)."""

    def __init__(self, model: Model, opt: Optimizer, data: FederatedDataset,
                 cfg: EngineConfig, *, mesh=None,
                 loss_fn: Callable | None = None):
        self.model, self.opt, self.data, self.cfg = model, opt, data, cfg
        self.mesh = mesh if mesh is not None else make_mediator_mesh()
        self._msize = int(self.mesh.shape["mediator"])

        sizes = [x.shape[0] for x in data.client_images]
        pad = _pad_multiple(max(sizes), cfg.local.batch_size)
        # packed ONCE: device-resident (K, pad, ...) buffers + masks
        xs, ys, mask = data.padded(pad)
        self._x = jnp.asarray(xs)
        self._y = jnp.asarray(ys)
        self._mask = jnp.asarray(mask)
        self._counts = data.client_counts()
        self._rng = np.random.default_rng(cfg.seed)

        # commit params to the replicated mesh sharding up front: round
        # outputs carry it, so an uncommitted init would cache-miss the
        # round executable once (a full recompile) on the second round
        from jax.sharding import NamedSharding
        replicated = NamedSharding(self.mesh, P())
        self.params = jax.device_put(model.init(jax.random.PRNGKey(cfg.seed)),
                                     replicated)
        self.comm = CommMeter(count_params(self.params))
        self.history: list[dict] = []
        self.last_schedule_stats: dict | None = None
        self.num_schedule_packs = 0             # host packing events (bench)
        self._schedule: tuple | None = None
        self._round = 0
        self._round_fn = self._build_round_fn(loss_fn)

    # ------------------------------------------------------------------
    # round program
    # ------------------------------------------------------------------
    def _build_round_fn(self, loss_fn):
        cfg = self.cfg
        parallel_clients = cfg.aggregate == "weights"
        if parallel_clients:
            client_update = make_client_update(self.model, self.opt, cfg.local,
                                               loss_fn=loss_fn)
        else:
            mediator_update = make_mediator_update(self.model, self.opt,
                                                   cfg.local,
                                                   cfg.mediator_epochs,
                                                   loss_fn=loss_fn)
        P_med = P("mediator")

        def _train(params, x_all, y_all, m_all, idx, slot, keys):
            # idx/slot/keys arrive as this device's (M_local, ...) shard;
            # x_all/y_all/m_all are the replicated client store.
            if parallel_clients:
                cid = idx[:, 0]
                ms = m_all[cid] * slot[:, :1]
                outs = jax.vmap(client_update, in_axes=(None, 0, 0, 0, 0))(
                    params, x_all[cid], y_all[cid], ms, keys)
                return outs, ms.sum(axis=1)
            ms = m_all[idx] * slot[..., None]
            outs = jax.vmap(mediator_update, in_axes=(None, 0, 0, 0, 0))(
                params, x_all[idx], y_all[idx], ms, keys)
            return outs, ms.sum(axis=(1, 2))

        train = shard_map(_train, self.mesh,
                          in_specs=(P(), P(), P(), P(), P_med, P_med, P_med),
                          out_specs=(P_med, P_med), manual_axes=("mediator",))

        def round_fn(params, x_all, y_all, m_all, idx, slot, keys):
            stacked, weights = train(params, x_all, y_all, m_all,
                                     idx, slot, keys)
            agg = self._aggregate(stacked, weights)
            if parallel_clients:
                return agg
            return jax.tree.map(lambda p, d: p + d, params, agg)

        donate = (0,) if cfg.donate_params else ()
        return jax.jit(round_fn, donate_argnums=donate)

    def _aggregate(self, stacked: PyTree, weights: jax.Array) -> PyTree:
        """Eq. 6 over the stacked (M, ...) mediator results."""
        if self.cfg.use_kernel_agg:
            from repro.kernels import ops as kops
            return kops.fedavg_agg_tree(stacked, weights)
        return weighted_average(stacked, weights)

    # ------------------------------------------------------------------
    # scheduling (host side: tiny integer work, no sample movement)
    # ------------------------------------------------------------------
    def _groups_for(self, sel: np.ndarray) -> list[list[int]]:
        cfg = self.cfg
        if cfg.schedule == "kld":
            meds = scheduling.reschedule(self._counts[sel], cfg.gamma)
            self.last_schedule_stats = scheduling.schedule_stats(meds)
            return [[int(sel[i]) for i in m.clients] for m in meds]
        if cfg.schedule == "random":
            if cfg.gamma == 1:      # FedAvg: selection order, one client each
                self.last_schedule_stats = None
                return [[int(k)] for k in sel]
            meds = scheduling.random_schedule(len(sel), cfg.gamma,
                                              self._counts[sel],
                                              seed=cfg.seed + self._round)
            self.last_schedule_stats = scheduling.schedule_stats(meds)
            return [[int(sel[i]) for i in m.clients] for m in meds]
        raise ValueError(f"unknown schedule {cfg.schedule!r}")

    def _pack_schedule(self, sel: np.ndarray) -> tuple:
        """Schedule -> device-resident gather plan: (idx, slot, m_real)."""
        groups = self._groups_for(sel)
        m_real = len(groups)
        m_pad = self.cfg.pad_mediators_to or m_real
        if m_pad < m_real:
            raise ValueError(
                f"pad_mediators_to={m_pad} smaller than the schedule "
                f"({m_real} mediators)")
        m_pad = _pad_multiple(m_pad, self._msize)
        idx = np.zeros((m_pad, self.cfg.gamma), np.int32)
        slot = np.zeros((m_pad, self.cfg.gamma), np.float32)
        for mi, clients in enumerate(groups):
            for ci, cid in enumerate(clients):
                idx[mi, ci] = cid
                slot[mi, ci] = 1.0
        self.num_schedule_packs += 1
        return jnp.asarray(idx), jnp.asarray(slot), m_real

    def _round_keys(self, m_real: int, m_pad: int) -> jax.Array:
        base = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed + 1),
                                  self._round)
        keys = jax.random.split(base, m_real)
        if m_pad > m_real:  # dummy mediators: any key is a no-op
            pad = jnp.zeros((m_pad - m_real,) + keys.shape[1:], keys.dtype)
            keys = jnp.concatenate([keys, pad])
        return keys

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def run_round(self) -> None:
        cfg = self.cfg
        c = min(cfg.clients_per_round, self.data.num_clients)
        if cfg.reschedule_every_round or self._schedule is None:
            sel = self._rng.choice(self.data.num_clients, size=c, replace=False)
            self._schedule = self._pack_schedule(sel)
        idx, slot, m_real = self._schedule
        keys = self._round_keys(m_real, idx.shape[0])
        self.params = self._round_fn(self.params, self._x, self._y, self._mask,
                                     idx, slot, keys)
        if cfg.aggregate == "weights":
            self.comm.fedavg_round(c)
        else:
            self.comm.astraea_round(c, cfg.gamma, cfg.mediator_epochs)
        self._round += 1

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        for _ in range(rounds):
            self.run_round()
            if self._round % eval_every == 0 or self._round == rounds:
                m = evaluate(self.model, self.params,
                             self.data.test_images, self.data.test_labels)
                m.update(round=self._round, traffic_mb=self.comm.megabytes)
                if self.last_schedule_stats:
                    m["mediator_kld_mean"] = self.last_schedule_stats["kld_mean"]
                self.history.append(m)
        return self.history
