"""Staleness-weighting policies and the simulated straggler model.

Both halves of the async round subsystem's "physics" live here, kept
deliberately free of any wall-clock dependence so trajectories are
reproducible bit-for-bit:

* **Staleness policies** map a wave's staleness ``s`` (how many server
  commits behind the wave's dispatch snapshot is when its contribution
  folds) to a discount factor ``lambda(s)`` applied to the wave's Eq. 6
  aggregation weights. Every policy returns **exactly 1.0 at s=0** --
  multiplying a float by the literal ``1.0`` is a bitwise no-op, which is
  what lets the ``S=0`` async trajectory reproduce the synchronous engine
  exactly (see ``core/async_engine.py``).

* **StragglerModel** assigns each mediator *slot* a deterministic slowdown
  factor drawn once from a config-seeded RNG (never from time.time() or
  real execution speed). A mediator's simulated training duration is
  ``factor * work`` where ``work`` counts its active client slots times
  mediator epochs -- the quantity a real heterogeneous MEC deployment's
  round time is proportional to. Factors are keyed by mediator index in
  the round schedule (slot ``i`` is the same logical mediator fleet slot
  every round -- Alg. 3 and the random schedule both emit a stable
  ``ceil(c / gamma)`` groups), not by client identity or device row:
  mediators sit on edge servers in the paper's architecture, so
  heterogeneity persists across reschedules and is independent of the
  engine's locality placement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import numpy as np

POLICIES = ("constant", "polynomial", "exponential")
STRAGGLER_MODELS = ("none", "fixed", "lognormal")


def make_staleness_policy(name: str, alpha: float = 0.5
                          ) -> Callable[[int], float]:
    """Build ``lambda(s)``, the staleness discount.

    * ``constant``: 1 for all s (FedBuff-style undiscounted buffering).
    * ``polynomial``: (1 + s)^-alpha (FedAsync's polynomial family).
    * ``exponential``: exp(-alpha * s).

    All policies return exactly ``1.0`` at ``s == 0``.
    """
    if name not in POLICIES:
        raise ValueError(f"unknown staleness policy {name!r}; "
                         f"expected one of {POLICIES}")
    if alpha < 0:
        raise ValueError("policy alpha must be >= 0")
    if name == "constant":
        return lambda s: 1.0
    if name == "polynomial":
        return lambda s: 1.0 if s <= 0 else float((1.0 + s) ** -alpha)
    return lambda s: 1.0 if s <= 0 else float(math.exp(-alpha * s))


@dataclass(frozen=True)
class StragglerSpec:
    """Config for the simulated heterogeneous mediator fleet.

    * ``none``: every slot runs at unit speed (all waves tie).
    * ``fixed``: a ``straggler_frac`` fraction of slots (chosen by the
      seeded RNG) run ``slowdown``x slower -- the paper-style "one slow
      edge server" scenario the benchmarks use (4x straggler).
    * ``lognormal``: factors ~ exp(N(0, sigma)), a continuous spread.
    """
    model: str = "none"
    straggler_frac: float = 0.25
    slowdown: float = 4.0
    sigma: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.model not in STRAGGLER_MODELS:
            raise ValueError(f"unknown straggler model {self.model!r}; "
                             f"expected one of {STRAGGLER_MODELS}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError("straggler_frac must be in [0, 1]")
        if self.slowdown < 1.0:
            raise ValueError("slowdown must be >= 1 (it is a slowdown)")


class StragglerModel:
    """Deterministic per-slot slowdown factors for ``num_slots`` mediators.

    Factors are drawn once at construction from ``spec.seed``; the same
    spec and slot count always produce the same fleet. No wall-clock
    enters the math anywhere.
    """

    def __init__(self, spec: StragglerSpec, num_slots: int):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        factors = np.ones(num_slots, np.float64)
        if spec.model == "fixed":
            k = int(round(spec.straggler_frac * num_slots))
            if k > 0:
                slow = rng.choice(num_slots, size=k, replace=False)
                factors[slow] = spec.slowdown
        elif spec.model == "lognormal":
            factors = np.exp(rng.normal(0.0, spec.sigma, num_slots))
        self.factors = factors

    def durations(self, work: np.ndarray) -> np.ndarray:
        """Simulated training time per mediator: ``factor * work``.

        ``work`` is per-mediator (schedule order); its length must not
        exceed the modeled slot count.
        """
        work = np.asarray(work, np.float64)
        if work.shape[0] > self.factors.shape[0]:
            raise ValueError(
                f"schedule has {work.shape[0]} mediators but the straggler "
                f"model covers {self.factors.shape[0]} slots")
        return self.factors[:work.shape[0]] * work
