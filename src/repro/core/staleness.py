"""Staleness-weighting policies, the straggler model, and adaptive S.

The async round subsystem's "physics" live here, kept deliberately free
of any wall-clock dependence so trajectories are reproducible
bit-for-bit:

* **Staleness policies** map a wave's staleness ``s`` (how many server
  commits behind the wave's dispatch snapshot is when its contribution
  folds) to a discount factor ``lambda(s)`` applied to the wave's Eq. 6
  aggregation weights. Every policy returns **exactly 1.0 at s=0** --
  multiplying a float by the literal ``1.0`` is a bitwise no-op, which is
  what lets the ``S=0`` async trajectory reproduce the synchronous engine
  exactly (see ``core/async_engine.py``).

* **StragglerModel** assigns deterministic slowdown factors drawn once
  from a config-seeded RNG (never from time.time() or real execution
  speed), at one of two granularities selected by ``StragglerSpec.level``:

  - ``"mediator"`` (historical): factors are keyed by mediator *slot*
    index in the round schedule (slot ``i`` is the same logical mediator
    fleet slot every round -- Alg. 3 and the random schedule both emit a
    stable ``ceil(c / gamma)`` groups). A mediator's simulated duration
    is ``factor * work`` where ``work`` counts its active client slots
    times mediator epochs. Mediators sit on edge servers in the paper's
    architecture, so heterogeneity persists across reschedules and is
    independent of the engine's locality placement.
  - ``"client"``: factors are keyed by *client id* -- the same client is
    slow every round, whatever mediator Alg. 3 packs it into (the
    device-level heterogeneity the edge literature emphasizes). A
    mediator trains its clients sequentially, so its duration is
    ``epochs * sum(factor_c for c in members)``
    (``durations_for_groups``). With every client at unit speed this
    degenerates bitwise to the mediator-level model with
    ``model="none"`` -- the float sum of ``k`` ones is exactly ``k`` --
    so speed-aware wave ordering reproduces the historical
    mediator-only ordering (``scheduling.partition_waves`` sorts stably).

* **AdaptiveStaleness** derives the staleness bound ``S`` from the
  *observed* commit-lag distribution instead of a static knob: an EWMA
  over per-wave commit lags (in rounds, on the virtual clock -- never
  wall time), clamped to ``[s_min, s_max]``. The update is the
  fixed-point form ``ewma += beta * (lag - ewma)``, so a constant lag
  stream keeps the estimate bitwise unchanged and the controller
  reproduces the fixed-S trajectory exactly (property-tested in
  tests/test_async_overlap.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

POLICIES = ("constant", "polynomial", "exponential")
STRAGGLER_MODELS = ("none", "fixed", "lognormal")
STRAGGLER_LEVELS = ("mediator", "client")


def make_staleness_policy(name: str, alpha: float = 0.5
                          ) -> Callable[[int], float]:
    """Build ``lambda(s)``, the staleness discount.

    * ``constant``: 1 for all s (FedBuff-style undiscounted buffering).
    * ``polynomial``: (1 + s)^-alpha (FedAsync's polynomial family).
    * ``exponential``: exp(-alpha * s).

    All policies return exactly ``1.0`` at ``s == 0``.
    """
    if name not in POLICIES:
        raise ValueError(f"unknown staleness policy {name!r}; "
                         f"expected one of {POLICIES}")
    if alpha < 0:
        raise ValueError("policy alpha must be >= 0")
    if name == "constant":
        return lambda s: 1.0
    if name == "polynomial":
        return lambda s: 1.0 if s <= 0 else float((1.0 + s) ** -alpha)
    return lambda s: 1.0 if s <= 0 else float(math.exp(-alpha * s))


@dataclass(frozen=True)
class StragglerSpec:
    """Config for the simulated heterogeneous mediator fleet.

    * ``none``: every slot runs at unit speed (all waves tie).
    * ``fixed``: a ``straggler_frac`` fraction of slots (chosen by the
      seeded RNG) run ``slowdown``x slower -- the paper-style "one slow
      edge server" scenario the benchmarks use (4x straggler).
    * ``lognormal``: factors ~ exp(N(0, sigma)), a continuous spread.

    ``level`` picks the granularity the factors are keyed by:
    ``"mediator"`` draws one factor per schedule slot (the historical
    edge-server model), ``"client"`` draws one per client id so slow
    *devices* persist across reschedules and drag whichever mediator
    absorbs them into the late waves (see module docstring).
    """
    model: str = "none"
    straggler_frac: float = 0.25
    slowdown: float = 4.0
    sigma: float = 0.5
    seed: int = 0
    level: str = "mediator"

    def __post_init__(self):
        if self.model not in STRAGGLER_MODELS:
            raise ValueError(f"unknown straggler model {self.model!r}; "
                             f"expected one of {STRAGGLER_MODELS}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError("straggler_frac must be in [0, 1]")
        if self.slowdown < 1.0:
            raise ValueError("slowdown must be >= 1 (it is a slowdown)")
        if self.level not in STRAGGLER_LEVELS:
            raise ValueError(f"unknown straggler level {self.level!r}; "
                             f"expected one of {STRAGGLER_LEVELS}")


def _draw_factors(spec: StragglerSpec, n: int) -> np.ndarray:
    """The one seeded factor draw shared by both keying levels."""
    rng = np.random.default_rng(spec.seed)
    factors = np.ones(n, np.float64)
    if spec.model == "fixed":
        k = int(round(spec.straggler_frac * n))
        if k > 0:
            slow = rng.choice(n, size=k, replace=False)
            factors[slow] = spec.slowdown
    elif spec.model == "lognormal":
        factors = np.exp(rng.normal(0.0, spec.sigma, n))
    return factors


class StragglerModel:
    """Deterministic slowdown factors for the simulated fleet.

    Factors are drawn once at construction from ``spec.seed``; the same
    spec and population always produce the same fleet. No wall-clock
    enters the math anywhere. Under ``level="mediator"`` the factors
    cover ``num_slots`` schedule slots and ``durations`` maps per-slot
    work; under ``level="client"`` they cover ``num_clients`` client ids
    and ``durations_for_groups`` derives each mediator's duration from
    its members' factors.
    """

    def __init__(self, spec: StragglerSpec, num_slots: int,
                 num_clients: int | None = None):
        self.spec = spec
        if spec.level == "client":
            if num_clients is None:
                raise ValueError("client-level straggler model needs "
                                 "num_clients")
            self.factors = _draw_factors(spec, num_clients)
        else:
            self.factors = _draw_factors(spec, num_slots)

    def durations(self, work: np.ndarray) -> np.ndarray:
        """Simulated training time per mediator: ``factor * work``.

        ``work`` is per-mediator (schedule order); its length must not
        exceed the modeled slot count. Mediator-level keying only --
        client-level models derive durations from the schedule's group
        membership (``durations_for_groups``).
        """
        if self.spec.level == "client":
            raise ValueError("client-level straggler model derives durations "
                             "from group membership; use "
                             "durations_for_groups(groups, epochs)")
        work = np.asarray(work, np.float64)
        if work.shape[0] > self.factors.shape[0]:
            raise ValueError(
                f"schedule has {work.shape[0]} mediators but the straggler "
                f"model covers {self.factors.shape[0]} slots")
        return self.factors[:work.shape[0]] * work

    def durations_for_groups(self, groups: Sequence[Sequence[int]],
                             epochs: int = 1) -> np.ndarray:
        """Per-mediator durations from client membership (client level).

        A mediator trains its members sequentially for ``epochs`` mediator
        epochs, so ``duration_m = epochs * sum(factor_c)`` over its
        members. With unit factors this is exactly ``epochs * len(group)``
        -- bitwise the mediator-level ``model="none"`` durations -- which
        is what keeps speed-agnostic schedules identical to the
        historical ordering (asserted in tests/test_async_overlap.py).
        """
        if self.spec.level != "client":
            raise ValueError("durations_for_groups requires level='client'")
        em = max(1, int(epochs))
        out = np.zeros(len(groups), np.float64)
        for g, members in enumerate(groups):
            ids = np.asarray(list(members), np.int64)
            if ids.size and ids.max() >= self.factors.shape[0]:
                raise ValueError(
                    f"group {g} references client {int(ids.max())} but the "
                    f"straggler model covers {self.factors.shape[0]} clients")
            out[g] = em * float(self.factors[ids].sum())
        return out


@dataclass(frozen=True)
class AdaptiveStalenessSpec:
    """Config for the adaptive staleness bound (``AdaptiveStaleness``).

    ``beta`` is the EWMA step toward each observed lag; ``init`` seeds
    the estimate (in rounds); the derived bound is
    ``clamp(ceil(ewma), s_min, s_max)``. ``s_min=s_max`` degenerates to
    the fixed-S knob.
    """
    s_min: int = 0
    s_max: int = 4
    beta: float = 0.25
    init: float = 0.0

    def __post_init__(self):
        if self.s_min < 0:
            raise ValueError("s_min must be >= 0")
        if self.s_max < self.s_min:
            raise ValueError("s_max must be >= s_min")
        if not 0.0 < self.beta <= 1.0:
            raise ValueError("beta must be in (0, 1]")
        if self.init < 0.0:
            raise ValueError("init must be >= 0")


class AdaptiveStaleness:
    """EWMA commit-lag estimator driving the staleness bound ``S``.

    ``observe(lag)`` folds one per-wave commit lag (in *rounds*, measured
    on the async engine's virtual clock -- wall time never enters) into
    the estimate with the fixed-point update ``ewma += beta*(lag - ewma)``:
    when ``lag == ewma`` the delta is exactly ``0.0`` and the estimate is
    bitwise unchanged, so a constant lag distribution holds the bound
    constant and the adaptive trajectory reproduces the fixed-S one
    bitwise. ``bound`` rounds the estimate up (a wave lagging 0.3 rounds
    on average still needs S=1 headroom to avoid blocking) and clamps to
    ``[s_min, s_max]``.
    """

    def __init__(self, spec: AdaptiveStalenessSpec):
        self.spec = spec
        self.ewma = float(spec.init)
        self.num_observed = 0

    def observe(self, lag: float) -> None:
        if lag < 0:
            raise ValueError(f"commit lag must be >= 0, got {lag}")
        self.ewma += self.spec.beta * (float(lag) - self.ewma)
        self.num_observed += 1

    @property
    def bound(self) -> int:
        # ceil with a tolerance so float dust from the EWMA (e.g. an
        # estimate of 1.0000000000000002 after mixed updates) does not
        # bump the bound a whole round
        raw = math.ceil(self.ewma - 1e-9) if self.ewma > 0 else 0
        return int(min(max(raw, self.spec.s_min), self.spec.s_max))
