"""Communication-traffic accounting (paper §IV-C).

FedAvg: each communication round moves the model down to and back up from
every selected client: ``2 c |w|``.

Astraea: mediators sit on the FL/MEC server, so the *WAN* traffic per
synchronization round is model down/up per online client per mediator epoch
plus server<->mediator exchange: ``2 |w| (ceil(c / gamma) + c)`` with the
client leg repeated ``E_m`` times when E_m > 1 (the paper's Table III varies
E_m at fixed formula; we account the client leg per mediator epoch, which
reproduces the Med1..Med4 ordering).

``|w|`` is parameter count x 4 bytes (fp32, as in the paper's TF models).
"""
from __future__ import annotations

from dataclasses import dataclass
import math


@dataclass
class CommMeter:
    num_params: int
    bytes_per_param: int = 4
    total_bytes: float = 0.0

    @property
    def model_bytes(self) -> float:
        return self.num_params * self.bytes_per_param

    @property
    def megabytes(self) -> float:
        return self.total_bytes / 2 ** 20

    def fedavg_round(self, c: int) -> None:
        self.total_bytes += 2 * c * self.model_bytes

    def astraea_round(self, c: int, gamma: int, mediator_epochs: int = 1) -> None:
        num_mediators = math.ceil(c / gamma)
        client_leg = 2 * c * self.model_bytes * mediator_epochs
        server_leg = 2 * num_mediators * self.model_bytes
        self.total_bytes += client_leg + server_leg
