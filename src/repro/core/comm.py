"""Communication-traffic accounting (paper §IV-C).

FedAvg: each communication round moves the model down to and back up from
every selected client: ``2 c |w|``.

Astraea: mediators sit on the FL/MEC server, so the *WAN* traffic per
synchronization round is model down/up per online client per mediator epoch
plus server<->mediator exchange: ``2 |w| (ceil(c / gamma) + c)`` with the
client leg repeated ``E_m`` times when E_m > 1 (the paper's Table III varies
E_m at fixed formula; we account the client leg per mediator epoch, which
reproduces the Med1..Med4 ordering).

``|w|`` is parameter count x 4 bytes (fp32, as in the paper's TF models).

Two accounting granularities share one ledger:

* per **round** (``fedavg_round`` / ``astraea_round``) -- the synchronous
  engine's unit;
* per **wave** (``fedavg_wave`` / ``astraea_wave``) -- the async engine
  charges each wave for its own clients' legs and its own mediators'
  server exchange. Because a round's waves partition both its clients and
  its mediators, the per-wave charges for one round sum to exactly the
  per-round formula (asserted in tests/test_comm.py).

``end_round`` snapshots the cumulative total into ``round_log`` so every
synchronization round leaves an auditable WAN-bytes trail (the paper's 82%
Table III claim is a ratio of these ledgers).

Alg. 2's one-off server->client plan broadcast (``plan_broadcast``) is
charged at initialization whenever augmentation is enabled -- a few hundred
bytes against megabyte model legs, but the ledger stays complete.  With
per-round adaptive plans the engine re-broadcasts the refreshed plan to
each reschedule's cohort, one ``plan_broadcast`` charge per reschedule.

**Two ledgers, never mixed.** ``total_bytes`` is the WAN ledger: traffic
that crosses the client<->server boundary, the quantity the paper's 82%
claim is a ratio of.  ``intra_pod_bytes`` is the datacenter ledger,
fed by three server-side sources, each with its own breakdown counter:

* ``model_axis_round`` -- the 2-D mesh's tensor-parallel param gather
  (``model_axis_tp_bytes``);
* ``store_stream`` -- the host->device copy the streaming client stores
  (``host``/``spilled``) make once per reschedule
  (``store_stream_bytes``);
* ``store_exchange`` -- the sharded store's per-round serve-slice
  exchange over the mediator interconnect (``store_exchange_bytes``);
  ragged mode charges the exact occupied slices, gather mode the full
  fixed-capacity all_gather.

Client placement and model parallelism are server-side deployment
details -- they move bytes over the pod interconnect or the host link,
not the WAN -- so none of them may inflate ``total_bytes`` (asserted in
tests/test_comm.py: the WAN ledger is invariant to store policy).

**Adapter-exchange mode.** With ``adapter_payload_bytes`` set (the engine
installs it from the LoRA mapping table, ``models/lora.py``), every model-
exchange leg ships the adapter state instead of the full tensors: the
same round/wave entry points charge ``legs * adapter_payload_bytes`` onto
``total_bytes`` and the ``wan_adapter_bytes`` breakdown, while
``wan_adapter_full_equiv_bytes`` accrues what those legs WOULD have cost
full-size -- so ``adapter_reduction_ratio`` (adapter/full, the scrapeable
Prometheus gauge) needs no external bookkeeping.  Without it the legs
charge full model bytes onto ``wan_full_delta_bytes``, the historical
behavior.  All counters stay integer-valued floats well below 2**53, so
the split is exact, not approximate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import math


@dataclass
class CommMeter:
    num_params: int
    bytes_per_param: int = 4
    total_bytes: float = 0.0            # WAN ledger (client <-> server)
    intra_pod_bytes: float = 0.0        # datacenter ledger (model-axis TP
    #                                     + client-store stream/exchange)
    # bytes of ONE model-exchange leg under LoRA adapter exchange; None =
    # full-delta exchange (every leg costs model_bytes)
    adapter_payload_bytes: float | None = None
    # WAN breakdown (wan_full_delta + wan_adapter sum to the exchange
    # share of total_bytes; plan broadcasts ride outside the split)
    wan_full_delta_bytes: float = 0.0
    wan_adapter_bytes: float = 0.0
    # full-size counterfactual of the adapter legs (ratio denominator)
    wan_adapter_full_equiv_bytes: float = 0.0
    # intra-pod breakdown (each sums into intra_pod_bytes)
    model_axis_tp_bytes: float = 0.0
    store_stream_bytes: float = 0.0
    store_exchange_bytes: float = 0.0
    # cumulative total_bytes after each synchronization round (one entry
    # per round, appended by the engine via end_round)
    round_log: list = field(default_factory=list)

    @property
    def model_bytes(self) -> float:
        return self.num_params * self.bytes_per_param

    @property
    def megabytes(self) -> float:
        return self.total_bytes / 2 ** 20

    @property
    def intra_pod_megabytes(self) -> float:
        return self.intra_pod_bytes / 2 ** 20

    # ---- intra-pod accounting (2-D mediator x model mesh) ----
    def model_axis_round(self, num_devices: int, model_size: int) -> None:
        """One round's tensor-parallel collectives on the pod interconnect:
        every device all-gathers the ``(model_size - 1) / model_size`` of
        the parameters it does not hold (the §8 gather; the reshard on the
        way out is a local slice, zero traffic).  Charged on the intra-pod
        ledger ONLY -- the WAN ledger behind the paper's traffic claims
        must be invariant to the server's model-parallel layout."""
        if model_size <= 1:
            return
        moved = (num_devices * self.model_bytes
                 * (model_size - 1) / model_size)
        self.model_axis_tp_bytes += moved
        self.intra_pod_bytes += moved

    def store_stream(self, nbytes: float) -> None:
        """Host->device streaming by a host/spilled client store, charged
        once per reschedule (the store reports the exact padded buffer
        bytes it device_put).  Intra-pod ledger only: placement policy
        must never move the WAN ledger."""
        self.store_stream_bytes += nbytes
        self.intra_pod_bytes += nbytes

    def store_exchange(self, nbytes: float) -> None:
        """Serve-slice exchange by the sharded client store over the
        mediator interconnect, charged every time the round program
        executes the current plan (per round, or per async wave)."""
        self.store_exchange_bytes += nbytes
        self.intra_pod_bytes += nbytes

    # ---- one-off accounting ----
    def plan_broadcast(self, num_entries: int, num_clients: int,
                       bytes_per_entry: int = 4) -> None:
        """Alg. 2 server->client broadcast of the per-class augmentation
        plan: a ``(num_classes,)`` int32 array down to every client, once
        at initialization.  Tiny next to a single model leg, but the WAN
        ledger is only auditable if every message is on it."""
        self.total_bytes += num_entries * bytes_per_entry * num_clients

    # ---- model-exchange legs (the one WAN charging primitive) ----
    def _exchange(self, legs: float) -> None:
        """Charge ``legs`` model-exchange legs on the WAN ledger, routed by
        payload mode: full tensors (``wan_full_delta_bytes``) or the LoRA
        adapter state (``wan_adapter_bytes``, with the full-size
        counterfactual accrued for the reduction ratio)."""
        if self.adapter_payload_bytes is None:
            moved = legs * self.model_bytes
            self.wan_full_delta_bytes += moved
        else:
            moved = legs * self.adapter_payload_bytes
            self.wan_adapter_bytes += moved
            self.wan_adapter_full_equiv_bytes += legs * self.model_bytes
        self.total_bytes += moved

    @property
    def adapter_reduction_ratio(self) -> float | None:
        """Adapter-vs-full WAN reduction: bytes actually shipped by the
        adapter legs over their full-size counterfactual (None before any
        adapter leg is charged)."""
        if self.wan_adapter_full_equiv_bytes == 0:
            return None
        return self.wan_adapter_bytes / self.wan_adapter_full_equiv_bytes

    # ---- per-round accounting (synchronous engine) ----
    def fedavg_round(self, c: int) -> None:
        self._exchange(2 * c)

    def astraea_round(self, c: int, gamma: int, mediator_epochs: int = 1) -> None:
        num_mediators = math.ceil(c / gamma)
        self._exchange(2 * c * mediator_epochs)     # client legs
        self._exchange(2 * num_mediators)           # server<->mediator legs

    # ---- per-wave accounting (async engine) ----
    def fedavg_wave(self, clients: int) -> None:
        """One async FedAvg wave: model down+up for this wave's clients."""
        self._exchange(2 * clients)

    def astraea_wave(self, clients: int, mediators: int,
                     mediator_epochs: int = 1) -> None:
        """One async Astraea wave: client legs for this wave's clients plus
        the server<->mediator exchange for this wave's mediators."""
        self._exchange(2 * clients * mediator_epochs)
        self._exchange(2 * mediators)

    # ---- per-round ledger ----
    def end_round(self) -> None:
        """Snapshot the cumulative WAN bytes at a round boundary."""
        self.round_log.append(self.total_bytes)

    # ---- telemetry export ----
    def ledger_totals(self) -> dict:
        """Every cumulative ledger and breakdown, keyed by the suffix the
        metrics registry publishes it under (``astraea_<key>``).  The obs
        layer mirrors these with ``Counter.set_total`` so each Prometheus
        sample equals the ledger value exactly -- keep this the single
        place that enumerates the meter's cumulative surfaces."""
        return {
            "wan_bytes_total": self.total_bytes,
            "wan_full_delta_bytes_total": self.wan_full_delta_bytes,
            "wan_adapter_bytes_total": self.wan_adapter_bytes,
            "wan_adapter_full_equiv_bytes_total":
                self.wan_adapter_full_equiv_bytes,
            "intra_pod_bytes_total": self.intra_pod_bytes,
            "model_axis_tp_bytes_total": self.model_axis_tp_bytes,
            "store_stream_bytes_total": self.store_stream_bytes,
            "store_exchange_bytes_total": self.store_exchange_bytes,
        }
