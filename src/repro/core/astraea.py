"""The Astraea server: rebalance -> reschedule -> train -> aggregate.

Workflow (paper Fig. 3):

1. **Initialization** -- clients report label histograms; server computes
   the global distribution.
2. **Rebalancing** (once, Alg. 2) -- server broadcasts the per-class
   augmentation plan; clients augment locally (random affine warps).
3. Each synchronization round: sample ``c`` online clients, run Alg. 3 to
   greedily pack them into mediators of <= gamma clients (min KLD to
   uniform), train every mediator in parallel (clients sequential inside,
   E_m mediator epochs), and FedAvg-aggregate the mediator deltas with
   weights n_m / n.

The mediator fleet is vmapped: mediators are padded to gamma client slots
with zero-mask dummies. Aggregation uses the ``fedavg_agg`` Pallas kernel
path when ``use_kernel_agg`` (flattened-parameter weighted reduction);
default is the pure-jnp ``weighted_average`` (same math, XLA-fused).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import augmentation, scheduling
from repro.core.comm import CommMeter
from repro.core.fl import LocalSpec, weighted_average, evaluate
from repro.core.mediator import make_mediator_update
from repro.data.federated import FederatedDataset
from repro.models.cnn import Model, count_params
from repro.optim.optimizers import Optimizer

PyTree = Any


def _pad_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass
class AstraeaTrainer:
    model: Model
    opt: Optimizer
    data: FederatedDataset
    clients_per_round: int                  # c
    gamma: int                              # max clients per mediator
    local: LocalSpec                        # B, E
    mediator_epochs: int = 1                # E_m
    alpha: float | None = 0.67              # augmentation factor; None = NoAug
    use_kernel_agg: bool = False
    reschedule_every_round: bool = False    # static client data -> schedule once
    seed: int = 0
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        # ---- Rebalancing phase (Alg. 2), once at initialization ----
        if self.alpha is not None:
            cx, cy, plan, extra = augmentation.rebalance_federation(
                jax.random.fold_in(key, 17), self.data.client_images,
                self.data.client_labels, self.data.num_classes, self.alpha)
            self.data = FederatedDataset(cx, cy, self.data.test_images,
                                         self.data.test_labels,
                                         self.data.num_classes, self.data.name)
            self.augmentation_plan = plan
            self.extra_storage_frac = extra
        else:
            self.augmentation_plan = None
            self.extra_storage_frac = 0.0

        sizes = [x.shape[0] for x in self.data.client_images]
        pad = _pad_multiple(max(sizes), self.local.batch_size)
        self._x, self._y, self._mask = self.data.padded(pad)
        self._counts = self.data.client_counts()
        self._rng = np.random.default_rng(self.seed)
        self.params = self.model.init(key)
        self.comm = CommMeter(count_params(self.params))
        self.last_schedule_stats: dict | None = None
        self._schedule_cache: dict | None = None

        mediator_update = make_mediator_update(self.model, self.opt, self.local,
                                               self.mediator_epochs)

        @jax.jit
        def round_fn(params, xs, ys, masks, keys):
            # xs: (M, gamma, pad, ...) -- vmap over mediators
            deltas = jax.vmap(mediator_update, in_axes=(None, 0, 0, 0, 0))(
                params, xs, ys, masks, keys)
            weights = masks.sum(axis=(1, 2))                     # n_m
            delta = self._aggregate(deltas, weights)
            return jax.tree.map(lambda p, d: p + d, params, delta)

        self._round_fn = round_fn
        self._round = 0

    # ---- aggregation (Eq. 6 over deltas) ----
    def _aggregate(self, deltas: PyTree, weights: jax.Array) -> PyTree:
        if self.use_kernel_agg:
            from repro.kernels import ops as kops
            return kops.fedavg_agg_tree(deltas, weights)
        return weighted_average(deltas, weights)

    # ---- scheduling phase (Alg. 3) ----
    def _mediators_for(self, sel: np.ndarray) -> list[list[int]]:
        meds = scheduling.reschedule(self._counts[sel], self.gamma)
        self.last_schedule_stats = scheduling.schedule_stats(meds)
        return [[int(sel[i]) for i in m.clients] for m in meds]

    def run_round(self) -> None:
        c = min(self.clients_per_round, self.data.num_clients)
        if self.reschedule_every_round or self._schedule_cache is None:
            sel = self._rng.choice(self.data.num_clients, size=c, replace=False)
            mediators = self._mediators_for(sel)
            self._schedule_cache = {"mediators": mediators}
        mediators = self._schedule_cache["mediators"]
        m_count = len(mediators)

        # pack into (M, gamma, ...) padded arrays
        sample_shape = self._x.shape[2:]
        pad = self._x.shape[1]
        xs = np.zeros((m_count, self.gamma, pad) + sample_shape, np.float32)
        ys = np.zeros((m_count, self.gamma, pad), np.int32)
        ms = np.zeros((m_count, self.gamma, pad), np.float32)
        for mi, clients in enumerate(mediators):
            for ci, cid in enumerate(clients):
                xs[mi, ci] = self._x[cid]
                ys[mi, ci] = self._y[cid]
                ms[mi, ci] = self._mask[cid]

        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), self._round), m_count)
        self.params = self._round_fn(self.params, jnp.asarray(xs), jnp.asarray(ys),
                                     jnp.asarray(ms), keys)
        self.comm.astraea_round(c, self.gamma, self.mediator_epochs)
        self._round += 1

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        for _ in range(rounds):
            self.run_round()
            if self._round % eval_every == 0 or self._round == rounds:
                m = evaluate(self.model, self.params,
                             self.data.test_images, self.data.test_labels)
                m.update(round=self._round, traffic_mb=self.comm.megabytes)
                if self.last_schedule_stats:
                    m["mediator_kld_mean"] = self.last_schedule_stats["kld_mean"]
                self.history.append(m)
        return self.history
