"""The Astraea server: rebalance -> reschedule -> train -> aggregate.

Workflow (paper Fig. 3):

1. **Initialization** -- clients report label histograms; server computes
   the global distribution.
2. **Rebalancing** (Alg. 2) -- server broadcasts the per-class
   augmentation plan; clients augment locally (random affine warps).
3. Each synchronization round: sample ``c`` online clients, run Alg. 3 to
   greedily pack them into mediators of <= gamma clients (min KLD to
   uniform), train every mediator in parallel (clients sequential inside,
   E_m mediator epochs), and FedAvg-aggregate the mediator deltas with
   weights n_m / n.

``aug_mode`` picks where step 2 executes:

* ``"online"`` (default) -- the plan is handed to the round engine and the
  resample+warp runs inside the jitted round program, redrawn every round
  (``augmentation.online_augment_batch``).  No augmented copy is ever
  materialized: client stores keep the raw federation (zero extra device
  storage), and Alg. 3 / Eq. 6 run on the expected post-augmentation
  histograms.  ``planned_extra_frac`` reports what the paper's Fig. 9
  storage cost *would have been*.
* ``"materialized"`` -- the historical pre-training host phase: every
  augmentation is generated up front and the federation rebuilt (the
  paper's deployment, with its ``extra_storage_frac`` cost).  Kept as the
  equivalence oracle for online mode.

The round itself is executed by ``core.engine.FLRoundEngine`` (the
device-resident, mediator-sharded round program); this class owns the
paper-specific rebalancing phase and presents the historical trainer API.
Aggregation uses the ``fedavg_agg`` Pallas kernel path when
``use_kernel_agg``; default is the pure-jnp ``weighted_average``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import augmentation
from repro.core.augmentation import AUG_MODES
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fl import LocalSpec
from repro.data.federated import FederatedDataset
from repro.models.cnn import Model
from repro.optim.optimizers import Optimizer


@dataclass
class AstraeaTrainer:
    model: Model
    opt: Optimizer
    data: FederatedDataset
    clients_per_round: int                  # c
    gamma: int                              # max clients per mediator
    local: LocalSpec                        # B, E
    mediator_epochs: int = 1                # E_m
    alpha: float | None = 0.67              # augmentation factor; None = NoAug
    aug_mode: str | None = "online"         # "online" | "materialized" | None
    # per-round adaptive rebalancing: recompute the Alg. 2 plan from the
    # selected cohort's label histograms at every reschedule (online mode
    # only; the refreshed plan is re-broadcast and metered per reschedule)
    adaptive_plan: bool = False
    use_kernel_agg: bool = False
    reschedule_every_round: bool = False    # static client data -> schedule once
    store: str = "replicated"               # client-store placement policy
    store_exchange: str = "ragged"          # sharded serve exchange mode
    # padded mediator count; defaults to ceil(c / gamma) -- the exact output
    # size of Alg. 3 -- so reschedules never re-jit the round executable
    pad_mediators_to: int | None = None
    # bounded-staleness async rounds (core/async_engine.py); None = the
    # synchronous barrier engine
    async_spec: object = None
    mesh: object = None                     # mediator mesh; None = all devices
    # model-axis size of the 2-D (mediator, model) mesh: each mediator
    # slice tensor-shards its parameter residency over this many devices
    # (launch/mesh.py:make_fl_mesh). None = 1-D mediator mesh (or the
    # ASTRAEA_MODEL_PARALLEL env default). Ignored when ``mesh`` is given.
    model_parallel: int | None = None
    # true tensor-parallel row compute over the model axis (§8 TP mode);
    # "auto" = on for TPU/GPU backends, gather oracle elsewhere
    tp_rows: object = "auto"
    # LoRA adapter-delta WAN exchange: adapter mapping-table rank built
    # from model.param_specs() (models/lora.py); None = full-delta legs
    lora_rank: int | None = None
    lora_alpha: float | None = None
    # optional obs.Telemetry handle threaded into the engine (host-side
    # spans + metrics; None = the zero-cost no-op stubs)
    telemetry: object = None
    seed: int = 0
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        # ---- Rebalancing phase (Alg. 2), shared with FedAvgTrainer ----
        phase = augmentation.resolve_aug_mode(self.data, self.alpha,
                                              self.aug_mode, self.seed)
        self.data = phase.data
        self.augmentation_plan = phase.plan
        self.extra_storage_frac = phase.extra_storage_frac  # realized
        self.planned_extra_frac = phase.planned_extra_frac  # avoided (online)
        engine_plan, adaptive_alpha = augmentation.resolve_engine_plan(
            phase, self.adaptive_plan, self.alpha)
        from repro.launch.mesh import resolve_fl_mesh
        mesh = resolve_fl_mesh(self.mesh, self.model_parallel)

        # donate_params=False: the historical trainer API let callers keep
        # references to trainer.params across rounds; donation (the engine
        # default) would invalidate those buffers on accelerators
        c_eff = min(self.clients_per_round, self.data.num_clients)
        pad_m = self.pad_mediators_to or -(-c_eff // self.gamma)
        self.engine = FLRoundEngine(
            self.model, self.opt, self.data,
            EngineConfig.astraea(
                clients_per_round=self.clients_per_round, gamma=self.gamma,
                local=self.local, mediator_epochs=self.mediator_epochs,
                use_kernel_agg=self.use_kernel_agg,
                reschedule_every_round=self.reschedule_every_round,
                store=self.store, store_exchange=self.store_exchange,
                pad_mediators_to=pad_m, tp_rows=self.tp_rows,
                lora_rank=self.lora_rank, lora_alpha=self.lora_alpha,
                donate_params=False, seed=self.seed),
            mesh=mesh, aug_plan=engine_plan,
            adaptive_aug_alpha=adaptive_alpha, telemetry=self.telemetry)
        if phase.mode == "materialized":
            # online mode charges this inside the engine; the materialized
            # phase broadcast the same plan before the engine existed
            self.engine.comm.plan_broadcast(self.data.num_classes,
                                            self.data.num_clients)
        if self.async_spec is not None:
            from repro.core.async_engine import AsyncRoundEngine
            self.runner = AsyncRoundEngine(self.engine, self.async_spec)
        else:
            self.runner = self.engine
        self.history = self.runner.history

    # ---- historical trainer surface, delegated to the engine ----
    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, value):
        self.engine.params = value

    @property
    def comm(self):
        return self.engine.comm

    @property
    def last_schedule_stats(self):
        return self.engine.last_schedule_stats

    @property
    def _round(self):
        return self.engine._round

    @_round.setter
    def _round(self, value):
        self.engine._round = value

    def run_round(self) -> None:
        self.runner.run_round()

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        return self.runner.fit(rounds, eval_every)
