"""The Astraea server: rebalance -> reschedule -> train -> aggregate.

Workflow (paper Fig. 3):

1. **Initialization** -- clients report label histograms; server computes
   the global distribution.
2. **Rebalancing** (once, Alg. 2) -- server broadcasts the per-class
   augmentation plan; clients augment locally (random affine warps).
3. Each synchronization round: sample ``c`` online clients, run Alg. 3 to
   greedily pack them into mediators of <= gamma clients (min KLD to
   uniform), train every mediator in parallel (clients sequential inside,
   E_m mediator epochs), and FedAvg-aggregate the mediator deltas with
   weights n_m / n.

The round itself is executed by ``core.engine.FLRoundEngine`` (the
device-resident, mediator-sharded round program); this class owns the
paper-specific rebalancing phase and presents the historical trainer API.
Aggregation uses the ``fedavg_agg`` Pallas kernel path when
``use_kernel_agg``; default is the pure-jnp ``weighted_average``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax

from repro.core import augmentation
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fl import LocalSpec
from repro.data.federated import FederatedDataset
from repro.models.cnn import Model
from repro.optim.optimizers import Optimizer


@dataclass
class AstraeaTrainer:
    model: Model
    opt: Optimizer
    data: FederatedDataset
    clients_per_round: int                  # c
    gamma: int                              # max clients per mediator
    local: LocalSpec                        # B, E
    mediator_epochs: int = 1                # E_m
    alpha: float | None = 0.67              # augmentation factor; None = NoAug
    use_kernel_agg: bool = False
    reschedule_every_round: bool = False    # static client data -> schedule once
    store: str = "replicated"               # client-store placement policy
    # padded mediator count; defaults to ceil(c / gamma) -- the exact output
    # size of Alg. 3 -- so reschedules never re-jit the round executable
    pad_mediators_to: int | None = None
    # bounded-staleness async rounds (core/async_engine.py); None = the
    # synchronous barrier engine
    async_spec: object = None
    mesh: object = None                     # mediator mesh; None = all devices
    seed: int = 0
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        # ---- Rebalancing phase (Alg. 2), once at initialization ----
        if self.alpha is not None:
            cx, cy, plan, extra = augmentation.rebalance_federation(
                jax.random.fold_in(key, 17), self.data.client_images,
                self.data.client_labels, self.data.num_classes, self.alpha)
            self.data = FederatedDataset(cx, cy, self.data.test_images,
                                         self.data.test_labels,
                                         self.data.num_classes, self.data.name)
            self.augmentation_plan = plan
            self.extra_storage_frac = extra
        else:
            self.augmentation_plan = None
            self.extra_storage_frac = 0.0

        # donate_params=False: the historical trainer API let callers keep
        # references to trainer.params across rounds; donation (the engine
        # default) would invalidate those buffers on accelerators
        c_eff = min(self.clients_per_round, self.data.num_clients)
        pad_m = self.pad_mediators_to or -(-c_eff // self.gamma)
        self.engine = FLRoundEngine(
            self.model, self.opt, self.data,
            EngineConfig.astraea(
                clients_per_round=self.clients_per_round, gamma=self.gamma,
                local=self.local, mediator_epochs=self.mediator_epochs,
                use_kernel_agg=self.use_kernel_agg,
                reschedule_every_round=self.reschedule_every_round,
                store=self.store, pad_mediators_to=pad_m,
                donate_params=False, seed=self.seed),
            mesh=self.mesh)
        if self.async_spec is not None:
            from repro.core.async_engine import AsyncRoundEngine
            self.runner = AsyncRoundEngine(self.engine, self.async_spec)
        else:
            self.runner = self.engine
        self.history = self.runner.history

    # ---- historical trainer surface, delegated to the engine ----
    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, value):
        self.engine.params = value

    @property
    def comm(self):
        return self.engine.comm

    @property
    def last_schedule_stats(self):
        return self.engine.last_schedule_stats

    @property
    def _round(self):
        return self.engine._round

    @_round.setter
    def _round(self, value):
        self.engine._round = value

    def run_round(self) -> None:
        self.runner.run_round()

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        return self.runner.fit(rounds, eval_every)
