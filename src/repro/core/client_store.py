"""Client data placement layer: where the packed ``(K, pad, ...)`` live.

The FL round engine never touches raw client arrays; it talks to a
``ClientStore`` that owns the packed per-client buffers and knows how to
turn a host-side gather schedule (``idx (M_pad, gamma)`` client ids +
0/1 ``slot`` mask) into per-slot device tensors inside the shard_mapped
round. Three placement policies trade memory for traffic:

===========  ====================  =======================================
policy       per-device bytes      per-schedule traffic
===========  ====================  =======================================
replicated   K * slice             none (gathers are device-local)
sharded      ceil(K / n) * slice   all_gather of <= min(M_pad * gamma,
                                   K_local) *scheduled* slices per shard
host         U_cap * slice         host->device copy of the <= c unique
             (U_cap = min(K, c))   scheduled clients, once per reschedule
===========  ====================  =======================================

``replicated`` is PR-1's behavior: every device holds the whole federation
(fastest, but K is bounded by one device's HBM). ``sharded`` partitions
the client axis over the ``mediator`` mesh axis: device ``d`` owns clients
``[d * K_local, (d+1) * K_local)``; at schedule time the store remaps each
mediator's global client ids into (a) direct reads from the local shard
when the mediator's device owns the client and (b) positions in a
``serve`` buffer of scheduled slices that each owner contributes to one
``all_gather`` -- only scheduled clients ride the interconnect, never the
store. ``host`` keeps the federation in host RAM and streams the compact
unique-scheduled slice (padded to the static capacity ``U_cap`` so the
round executable never re-specializes) to device once per reschedule: the
federation only has to fit in host memory, and device residency is O(c).

All three are **bit-identical**: gathers and copies move exact values, the
round program consumes identical per-slot tensors, and the engine
replicates the stacked mediator outputs before aggregation so the FP
reduction order never depends on the mesh (see ``FLRoundEngine``).

Locality: the ``sharded`` store routes mediator placement through
``scheduling.place_mediators`` so each mediator lands on the shard owning
most of its clients -- minimizing occupied ``all_gather`` slots (the
cross-shard fetch count is surfaced in ``last_placement_stats``). The
serve capacity is the static worst case ``min(M_pad * gamma, K_local)``,
so reschedules at fixed M never change shapes and never re-jit.

2-D mesh note: on a ``(mediator, model)`` mesh every placement policy
partitions the *client* axis over the mediator submesh rows only -- the
specs never mention ``model``, so each mediator row's client slice is
replicated across its model columns and the schedule-time remapping
(ownership = mediator shard) is untouched by tensor parallelism.  The
engine reports its model-axis parameter residency through
``note_param_residency`` so ``stats()`` audits both halves of device
memory: client bytes (partitioned by *policy* over ``mediator``) and param
bytes (partitioned by the *rule tables* over ``model``).

Augmentation note: stores always hold the federation **as packed** -- they
never see augmented copies.  Under the online rebalancing pipeline the
engine augments inside the round program, so per-device residency stays at
the raw pre-augmentation size under every policy; only the historical
materialized mode inflates what arrives here (because the *trainer*
rebuilt the federation before packing).  ``stats()`` surfaces the
policy/residency pair the benchmarks and byte tests audit.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import scheduling
from repro.launch.mesh import mediator_sharding, replicated_sharding

Arrays = Any

POLICIES = ("replicated", "sharded", "host")


def _bytes(*arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


class ClientStore:
    """Base policy: the engine-facing contract.

    * ``data_specs`` / ``plan_specs``: PartitionSpecs for the two argument
      groups the store feeds into the shard_mapped round body.
    * ``place(groups, m_pad)``: assign mediators to padded schedule rows
      (``row_to_group``, -1 = dummy); row ``r`` runs on device
      ``r // (m_pad // n)``.
    * ``plan(idx, slot)``: schedule-time index remapping; returns
      ``(data_args, plan_args)`` for ``run_round``. Called once per
      reschedule, never per round.
    * ``slot_data(data_args, plan_args)``: traced *inside* shard_map;
      returns this device's ``(M_local, gamma, pad, ...)`` x/y/mask
      slot tensors (mask still unscaled by the slot mask).
    """

    policy: str
    permutes_rows = False
    # (per_device_param_bytes, model_axis) reported by the engine after it
    # places the model parameters (sharded over the ``model`` mesh axis on
    # a 2-D mesh); None until an engine adopts the store
    param_residency: tuple[int, int] | None = None

    def note_param_residency(self, per_device_bytes: int,
                             model_axis: int = 1) -> None:
        """Record the engine's per-device parameter residency so
        ``stats()`` covers the whole device-memory picture."""
        self.param_residency = (int(per_device_bytes), int(model_axis))

    def place(self, groups: list[list[int]], m_pad: int) -> np.ndarray:
        row_to_group = np.full(m_pad, -1, np.int64)
        row_to_group[:len(groups)] = np.arange(len(groups))
        return row_to_group

    def plan(self, idx: np.ndarray, slot: np.ndarray):
        raise NotImplementedError

    def slot_data(self, data: Arrays, plan: Arrays):
        raise NotImplementedError

    def per_device_bytes(self) -> int:
        raise NotImplementedError

    def stats(self) -> dict:
        """Residency audit row: policy + per-device client bytes
        (benchmarks and the online-aug byte tests compare this against the
        raw pack), plus the engine's per-device *param* bytes and model
        axis once an engine has adopted the store (the 2-D mesh tests
        assert the model-axis reduction here)."""
        row = {"policy": self.policy,
               "per_device_bytes": self.per_device_bytes()}
        if self.param_residency is not None:
            row["per_device_param_bytes"], row["model_axis"] = \
                self.param_residency
        return row


class ReplicatedStore(ClientStore):
    """PR-1 behavior: the full packed store on every device."""

    policy = "replicated"
    data_specs = (P(), P(), P())
    plan_specs = (P("mediator"),)

    def __init__(self, xs, ys, mask, mesh):
        rep = replicated_sharding(mesh)
        self._x = jax.device_put(jnp.asarray(xs), rep)
        self._y = jax.device_put(jnp.asarray(ys), rep)
        self._m = jax.device_put(jnp.asarray(mask), rep)

    def plan(self, idx, slot):
        return (self._x, self._y, self._m), (jnp.asarray(idx),)

    def slot_data(self, data, plan):
        x_all, y_all, m_all = data
        (idx,) = plan
        return x_all[idx], y_all[idx], m_all[idx]

    def per_device_bytes(self) -> int:
        return _bytes(self._x, self._y, self._m)


class ShardedStore(ClientStore):
    """Client axis partitioned over the ``mediator`` mesh axis.

    Schedule-time remapping (``plan``) splits every active slot ``(r, g)``
    into *local* (client owned by row ``r``'s device: read straight from
    the shard at ``lpos``) or *remote* (the owner appends the client --
    deduplicated -- to its ``serve`` list; the slot reads the
    ``all_gather``-ed serve buffers at ``rpos``). Serve lists are padded
    to the static capacity ``F = min(M_pad * gamma, K_local)`` -- a device
    can never serve more distinct clients than it owns, nor more than the
    schedule holds -- so the gather program is shape-stable across
    reschedules.
    """

    policy = "sharded"
    permutes_rows = True
    data_specs = (P("mediator"), P("mediator"), P("mediator"))
    plan_specs = (P("mediator"), P("mediator"), P("mediator"), P("mediator"))

    def __init__(self, xs, ys, mask, mesh):
        self._n = int(mesh.shape["mediator"])
        k = xs.shape[0]
        k_pad = ((k + self._n - 1) // self._n) * self._n
        if k_pad > k:                       # dummy clients: zero mask rows
            grow = lambda a: np.concatenate(
                [a, np.zeros((k_pad - k,) + a.shape[1:], a.dtype)])
            xs, ys, mask = grow(xs), grow(ys), grow(mask)
        self._k_local = k_pad // self._n
        shard = mediator_sharding(mesh)
        self._x = jax.device_put(jnp.asarray(xs), shard)
        self._y = jax.device_put(jnp.asarray(ys), shard)
        self._m = jax.device_put(jnp.asarray(mask), shard)
        self.last_placement_stats: dict | None = None

    def owner(self, cid: int) -> int:
        return cid // self._k_local

    def place(self, groups, m_pad):
        row_to_group, stats = scheduling.place_mediators(
            groups, self._n, m_pad // self._n, self.owner)
        self.last_placement_stats = stats
        return row_to_group

    def plan(self, idx, slot):
        m_pad, gamma = idx.shape
        m_local = m_pad // self._n
        f = max(1, min(m_pad * gamma, self._k_local))
        serve = np.zeros((self._n, f), np.int32)
        served: dict[int, tuple[int, int]] = {}   # cid -> (owner, slot)
        fill = [0] * self._n
        loc = np.ones((m_pad, gamma), bool)       # inactive slots: local row 0
        lpos = np.zeros((m_pad, gamma), np.int32)
        rpos = np.zeros((m_pad, gamma), np.int32)
        for r, g in np.argwhere(slot > 0):
            cid = int(idx[r, g])
            own = self.owner(cid)
            if own == r // m_local:
                lpos[r, g] = cid % self._k_local
                continue
            if cid not in served:
                served[cid] = (own, fill[own])
                serve[own, fill[own]] = cid % self._k_local
                fill[own] += 1
            own, j = served[cid]
            loc[r, g] = False
            rpos[r, g] = own * f + j
        if self.last_placement_stats is not None:
            self.last_placement_stats["serve_capacity"] = int(self._n * f)
            self.last_placement_stats["serve_occupied"] = int(sum(fill))
        return ((self._x, self._y, self._m),
                (jnp.asarray(serve), jnp.asarray(loc), jnp.asarray(lpos),
                 jnp.asarray(rpos)))

    def slot_data(self, data, plan):
        serve, loc, lpos, rpos = plan
        srv = serve.reshape(-1)                   # this device's (F,) serve list

        def pick(shard):
            gathered = jax.lax.all_gather(shard[srv], "mediator", tiled=True)
            local = shard[lpos]                   # (M_local, gamma, pad, ...)
            remote = gathered[rpos]
            sel = loc.reshape(loc.shape + (1,) * (local.ndim - 2))
            return jnp.where(sel, local, remote)

        return tuple(pick(a) for a in data)

    def per_device_bytes(self) -> int:
        return _bytes(self._x, self._y, self._m) // self._n


class HostStore(ClientStore):
    """Host-RAM federation; per-schedule slices streamed to device.

    The packed store never leaves the host. Each reschedule device_puts
    the <= ``U_cap`` *unique* scheduled clients (padded to the static
    capacity so shapes, and hence the compiled round, are stable) and
    remaps the gather indices into that compact buffer -- the round then
    runs exactly like the replicated store over the small slice.
    """

    policy = "host"
    data_specs = (P(), P(), P())
    plan_specs = (P("mediator"),)

    def __init__(self, xs, ys, mask, mesh, capacity):
        self._xs, self._ys, self._mask = xs, ys, mask   # host numpy
        self._cap = max(1, min(xs.shape[0], capacity))
        self._rep = replicated_sharding(mesh)
        self._streamed_bytes = 0

    def plan(self, idx, slot):
        uniq = np.unique(idx[slot > 0])
        if uniq.size > self._cap:
            raise ValueError(f"schedule touches {uniq.size} unique clients; "
                             f"host store capacity is {self._cap}")
        remap = np.zeros(self._xs.shape[0], np.int32)
        remap[uniq] = np.arange(uniq.size, dtype=np.int32)
        idx_c = np.where(slot > 0, remap[idx], 0).astype(np.int32)

        def stream(a):
            out = np.zeros((self._cap,) + a.shape[1:], a.dtype)
            out[:uniq.size] = a[uniq]
            return jax.device_put(jnp.asarray(out), self._rep)

        data = (stream(self._xs), stream(self._ys), stream(self._mask))
        self._streamed_bytes += _bytes(*data)
        return data, (jnp.asarray(idx_c),)

    slot_data = ReplicatedStore.slot_data

    def per_device_bytes(self) -> int:
        slice_bytes = _bytes(self._xs[:1], self._ys[:1], self._mask[:1])
        return self._cap * slice_bytes


def build_client_store(policy: str, xs, ys, mask, mesh, *,
                       capacity: int | None = None) -> ClientStore:
    """Build the packed client store under ``policy`` (see module docstring)."""
    if policy == "replicated":
        return ReplicatedStore(xs, ys, mask, mesh)
    if policy == "sharded":
        return ShardedStore(xs, ys, mask, mesh)
    if policy == "host":
        return HostStore(xs, ys, mask, mesh,
                         capacity if capacity is not None else xs.shape[0])
    raise ValueError(f"unknown client-store policy {policy!r}; "
                     f"expected one of {POLICIES}")
