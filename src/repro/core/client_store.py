"""Client data placement layer: where the packed ``(K, pad, ...)`` live.

The FL round engine never touches raw client arrays; it talks to a
``ClientStore`` that owns the packed per-client buffers and knows how to
turn a host-side gather schedule (``idx (M_pad, gamma)`` client ids +
0/1 ``slot`` mask) into per-slot device tensors inside the shard_mapped
round. Four placement policies trade memory for traffic:

===========  ====================  =========================================
policy       per-device bytes      per-schedule / per-round traffic
===========  ====================  =========================================
replicated   K * slice             none (gathers are device-local)
sharded      ceil(K / n) * slice   per ROUND, serve-slice exchange over the
                                   mediator interconnect -- ``ragged``
                                   (default): each serve slice rides a
                                   point-to-point ppermute ring to exactly
                                   the rows that read it (bytes = occupied
                                   pair slots); ``gather``: the historical
                                   fixed-capacity all_gather of n*F slices
                                   to every device
host         U_cap * slice         per RESCHEDULE, host->device copy of the
             (U_cap = min(K, c))   <= c unique scheduled clients
spilled      U_cap * slice         same stream as ``host``, but the packed
             (+ LRU row cache      federation lives in a disk/mmap tier (or
             on the host,          a lazy per-client synthesizer); up to
             default 2*U_cap)      ``prefetch_depth`` future reschedules'
                                   unique clients prefetch on background
                                   threads while the current round computes,
                                   and rows reused across schedules come
                                   from the host-side LRU cache instead of
                                   disk (LRU-evicted on overflow)
===========  ====================  =========================================

``replicated`` is PR-1's behavior: every device holds the whole federation
(fastest, but K is bounded by one device's HBM). ``sharded`` partitions
the client axis over the ``mediator`` mesh axis: device ``d`` owns clients
``[d * K_local, (d+1) * K_local)``; at schedule time the store remaps each
mediator's global client ids into (a) direct reads from the local shard
when the mediator's device owns the client and (b) positions in per-device
exchange buffers -- only scheduled clients ride the interconnect, never the
store. ``host`` keeps the federation in host RAM and streams the compact
unique-scheduled slice (padded to the static capacity ``U_cap`` so the
round executable never re-specializes) to device once per reschedule.
``spilled`` is the million-client tier: device residency stays O(c) and the
*host* footprint drops to the U_cap-row cache -- the federation itself is a
``MmapClients`` disk tier (packed arrays spilled to memmaps) or any lazy
row source (e.g. ``data.synthetic.StreamingFederation``, which synthesizes
a client's samples deterministically on demand, so a K=1e6 federation
never materializes anywhere).

All four are **bit-identical**: gathers, permutes and copies move exact
values, the round program consumes identical per-slot tensors, and the
engine replicates the stacked mediator outputs before aggregation so the
FP reduction order never depends on the mesh (see ``FLRoundEngine``).
Prefetched rows are produced by the same fetch path as synchronous reads,
so the spill tier's overlap changes *when* bytes move, never which bytes.

Exchange accounting: stores report what their plan moves -- the engine
charges ``last_stream_bytes`` (host->device, once per reschedule) and
``exchange_bytes_per_round`` (mediator interconnect, every round the plan
executes) onto the ``CommMeter`` **intra-pod** ledger, keyed separately
from the model-axis collectives. The ragged exchange is charged the exact
occupied pair slots (what a true ragged collective ships); the historical
``gather`` mode is charged its full fixed capacity ``n * (n-1) * F``
slices, which is what ``all_gather`` physically moves. The WAN ledger is
invariant to the placement policy by construction -- placement is a
server-side deployment detail (asserted in tests/test_comm.py).

Locality: the ``sharded`` store routes mediator placement through
``scheduling.place_mediators`` so each mediator lands on the shard owning
most of its clients -- minimizing occupied exchange slots (the cross-shard
fetch count is surfaced in ``last_placement_stats``). Capacities are
static worst cases (``F = min(M_pad * gamma, K_local)`` for the gather
serve buffer, ``R = min(M_local * gamma, K_local)`` per ragged pair hop),
so reschedules at fixed M never change shapes and never re-jit; the
*accounted* ragged bytes are the occupied slots, the honest traffic of a
shape-dynamic deployment.

2-D mesh note: on a ``(mediator, model)`` mesh every placement policy
partitions the *client* axis over the mediator submesh rows only -- the
specs never mention ``model``, so each mediator row's client slice is
replicated across its model columns and the schedule-time remapping
(ownership = mediator shard) is untouched by tensor parallelism.  The
engine reports its model-axis parameter residency through
``note_param_residency`` so ``stats()`` audits both halves of device
memory: client bytes (partitioned by *policy* over ``mediator``) and param
bytes (partitioned by the *rule tables* over ``model``).

Augmentation note: stores always hold the federation **as packed** -- they
never see augmented copies.  Under the online rebalancing pipeline the
engine augments inside the round program, so per-device residency stays at
the raw pre-augmentation size under every policy; only the historical
materialized mode inflates what arrives here (because the *trainer*
rebuilt the federation before packing).  ``stats()`` surfaces the
policy/residency pair the benchmarks and byte tests audit.
"""
from __future__ import annotations

import os
import tempfile
import threading
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import scheduling
from repro.launch.mesh import (mediator_sharding, replicated_sharding,
                               ring_permutation)
from repro.obs.telemetry import NULL_TELEMETRY

Arrays = Any

POLICIES = ("replicated", "sharded", "host", "spilled")
EXCHANGES = ("ragged", "gather")


def _bytes(*arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


# --------------------------------------------------------------------------
# Row sources: where the packed federation physically lives.  The streaming
# stores (host / spilled) read batches of client rows through this tiny
# protocol -- ``num_clients``, ``row_specs`` (trailing shape + dtype per
# x/y/mask array), ``nbytes_per_client`` and ``rows(ids)`` -- so the same
# store code serves RAM arrays, a disk/mmap spill tier, or a lazy
# synthesizer that never materializes the federation at all.
# --------------------------------------------------------------------------

class PackedClients:
    """The packed ``(K, pad, ...)`` federation held in host RAM."""

    def __init__(self, xs, ys, mask):
        self._arrays = (np.asarray(xs), np.asarray(ys), np.asarray(mask))

    @property
    def num_clients(self) -> int:
        return int(self._arrays[0].shape[0])

    @property
    def row_specs(self) -> tuple:
        return tuple((a.shape[1:], a.dtype) for a in self._arrays)

    @property
    def nbytes_per_client(self) -> int:
        return _bytes(*(a[:1] for a in self._arrays))

    def rows(self, ids: np.ndarray) -> tuple:
        return tuple(a[ids] for a in self._arrays)


class MmapClients:
    """Disk/mmap tier: the packed federation spilled to per-array memmaps.

    Construction writes each packed array once and drops the RAM copy; row
    reads fancy-index the memmaps, touching only the requested clients'
    pages. Reads are deterministic (plain bytes), which is what makes
    prefetched and synchronously-streamed slices bit-identical.
    """

    def __init__(self, xs, ys, mask, spill_dir: str | None = None):
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="astraea-spill-")
        os.makedirs(self.spill_dir, exist_ok=True)
        self._maps = []
        for name, a in (("x", xs), ("y", ys), ("m", mask)):
            a = np.asarray(a)
            mm = np.memmap(os.path.join(self.spill_dir, f"clients_{name}.mmap"),
                           dtype=a.dtype, mode="w+", shape=a.shape)
            mm[:] = a
            mm.flush()
            self._maps.append(mm)

    @property
    def num_clients(self) -> int:
        return int(self._maps[0].shape[0])

    @property
    def row_specs(self) -> tuple:
        return tuple((a.shape[1:], a.dtype) for a in self._maps)

    @property
    def nbytes_per_client(self) -> int:
        return _bytes(*(a[:1] for a in self._maps))

    def rows(self, ids: np.ndarray) -> tuple:
        # fancy indexing a memmap materializes exactly the requested rows
        return tuple(np.asarray(a[ids]) for a in self._maps)


class ClientStore:
    """Base policy: the engine-facing contract.

    * ``data_specs`` / ``plan_specs``: PartitionSpecs for the two argument
      groups the store feeds into the shard_mapped round body.
    * ``place(groups, m_pad)``: assign mediators to padded schedule rows
      (``row_to_group``, -1 = dummy); row ``r`` runs on device
      ``r // (m_pad // n)``.
    * ``plan(idx, slot)``: schedule-time index remapping; returns
      ``(data_args, plan_args)`` for ``run_round``. Called once per
      reschedule, never per round.
    * ``slot_data(data_args, plan_args)``: traced *inside* shard_map;
      returns this device's ``(M_local, gamma, pad, ...)`` x/y/mask
      slot tensors (mask still unscaled by the slot mask).

    Traffic surface (read by the engine, charged on the CommMeter's
    intra-pod ledger): ``last_stream_bytes`` is what the latest ``plan``
    moved host->device (once per reschedule); ``exchange_bytes_per_round``
    is what every execution of the current plan moves over the mediator
    interconnect (the sharded store's serve exchange).
    """

    policy: str
    permutes_rows = False
    last_stream_bytes: int = 0
    exchange_bytes_per_round: int = 0
    # (per_device_param_bytes, model_axis) reported by the engine after it
    # places the model parameters (sharded over the ``model`` mesh axis on
    # a 2-D mesh); None until an engine adopts the store
    param_residency: tuple[int, int] | None = None
    # optional obs.Telemetry handle (the adopting engine installs its own;
    # the default no-op singleton keeps standalone stores zero-cost)
    telemetry = NULL_TELEMETRY

    def note_param_residency(self, per_device_bytes: int,
                             model_axis: int = 1) -> None:
        """Record the engine's per-device parameter residency so
        ``stats()`` covers the whole device-memory picture."""
        self.param_residency = (int(per_device_bytes), int(model_axis))

    def place(self, groups: list[list[int]], m_pad: int) -> np.ndarray:
        row_to_group = np.full(m_pad, -1, np.int64)
        row_to_group[:len(groups)] = np.arange(len(groups))
        return row_to_group

    def plan(self, idx: np.ndarray, slot: np.ndarray):
        raise NotImplementedError

    def slot_data(self, data: Arrays, plan: Arrays):
        raise NotImplementedError

    def per_device_bytes(self) -> int:
        raise NotImplementedError

    def stats(self) -> dict:
        """Residency/traffic audit row with ONE schema for all policies.

        Every policy returns the same key set -- features a policy lacks
        report ``0`` (counters) or ``None`` (identifiers) -- so the
        metrics registry and dashboards never branch per policy:

        ======================== ============================== ==========
        key                      meaning                        inactive
        ======================== ============================== ==========
        policy                   placement policy name          --
        per_device_bytes         resident client bytes/device   --
        per_device_param_bytes   engine param bytes/device      None
        model_axis               param model-shard factor       None
        exchange                 sharded serve exchange mode    None
        exchange_bytes_per_round serve-exchange bytes/round     0
        streamed_bytes           cumulative host->device bytes  0
        num_streams              host->device stream events     0
        prefetch_hits            background stages consumed     0
        prefetch_misses          stages discarded (mismatch)    0
        prefetch_depth           queued background stages cap   0
        cache_hit_rows           rows served from the LRU cache 0
        tier_rows                rows read from the spill tier  0
        lru_rows                 LRU row-cache capacity (rows)  0
        lru_evictions            rows evicted from the LRU      0
        spill_dir                mmap tier directory            None
        ======================== ============================== ==========

        ``per_device_param_bytes``/``model_axis`` stay ``None`` until an
        engine adopts the store (the 2-D mesh tests assert the model-axis
        reduction here); benchmarks and the online-aug byte tests compare
        ``per_device_bytes`` against the raw pack.
        """
        ppb, axis = self.param_residency or (None, None)
        return {
            "policy": self.policy,
            "per_device_bytes": self.per_device_bytes(),
            "per_device_param_bytes": ppb,
            "model_axis": axis,
            "exchange": getattr(self, "exchange", None),
            "exchange_bytes_per_round": self.exchange_bytes_per_round,
            "streamed_bytes": getattr(self, "_streamed_bytes", 0),
            "num_streams": getattr(self, "num_streams", 0),
            "prefetch_hits": getattr(self, "prefetch_hits", 0),
            "prefetch_misses": getattr(self, "prefetch_misses", 0),
            "prefetch_depth": getattr(self, "prefetch_depth", 0),
            "cache_hit_rows": getattr(self, "cache_hit_rows", 0),
            "tier_rows": getattr(self, "tier_rows", 0),
            "lru_rows": getattr(self, "lru_rows", 0),
            "lru_evictions": getattr(self, "lru_evictions", 0),
            "spill_dir": getattr(getattr(self, "_src", None),
                                 "spill_dir", None),
        }


class ReplicatedStore(ClientStore):
    """PR-1 behavior: the full packed store on every device."""

    policy = "replicated"
    data_specs = (P(), P(), P())
    plan_specs = (P("mediator"),)

    def __init__(self, xs, ys, mask, mesh):
        rep = replicated_sharding(mesh)
        self._x = jax.device_put(jnp.asarray(xs), rep)
        self._y = jax.device_put(jnp.asarray(ys), rep)
        self._m = jax.device_put(jnp.asarray(mask), rep)

    def plan(self, idx, slot):
        return (self._x, self._y, self._m), (jnp.asarray(idx),)

    def slot_data(self, data, plan):
        x_all, y_all, m_all = data
        (idx,) = plan
        return x_all[idx], y_all[idx], m_all[idx]

    def per_device_bytes(self) -> int:
        return _bytes(self._x, self._y, self._m)


class ShardedStore(ClientStore):
    """Client axis partitioned over the ``mediator`` mesh axis.

    Schedule-time remapping (``plan``) splits every active slot ``(r, g)``
    into *local* (client owned by row ``r``'s device: read straight from
    the shard at ``lpos``) or *remote* (read from exchanged serve buffers
    at ``rpos``). The split and dedup are fully vectorized numpy --
    ``np.nonzero`` row-major order reproduces the historical per-slot
    visit order exactly, so the emitted plan tensors are byte-identical to
    the old interpreter loop (which cost O(M_pad * gamma) python per
    reschedule and stalled large-M schedules).

    Two exchange modes, bit-identical trajectories:

    * ``ragged`` (default): a point-to-point ppermute ring. At hop
      ``s = 1..n-1`` shard ``o`` ships shard ``(o+s) % n`` exactly the
      slices that shard's rows read (deduplicated per (owner, reader)
      pair, padded to the static per-pair capacity
      ``R = min(M_local * gamma, K_local)``). A slice wanted by no remote
      row never rides the interconnect; the accounted bytes are the
      occupied pair slots.
    * ``gather``: the historical fixed-capacity ``all_gather`` -- every
      device receives every shard's full ``F = min(M_pad * gamma,
      K_local)``-slice serve buffer (globally deduplicated), moving
      ``n * (n-1) * F`` slices per round regardless of who reads what.
      Kept as the equivalence oracle and the bytes baseline.
    """

    policy = "sharded"
    permutes_rows = True
    exchange = "gather"       # class default keeps plan()-only construction
    data_specs = (P("mediator"), P("mediator"), P("mediator"))
    plan_specs = (P("mediator"), P("mediator"), P("mediator"), P("mediator"))

    def __init__(self, xs, ys, mask, mesh, *, exchange: str = "ragged"):
        if exchange not in EXCHANGES:
            raise ValueError(f"unknown exchange {exchange!r}; "
                             f"expected one of {EXCHANGES}")
        self.exchange = exchange
        self._n = int(mesh.shape["mediator"])
        k = xs.shape[0]
        k_pad = ((k + self._n - 1) // self._n) * self._n
        if k_pad > k:                       # dummy clients: zero mask rows
            grow = lambda a: np.concatenate(
                [a, np.zeros((k_pad - k,) + a.shape[1:], a.dtype)])
            xs, ys, mask = grow(xs), grow(ys), grow(mask)
        self._k_local = k_pad // self._n
        self._slice_nbytes = _bytes(xs[:1], ys[:1], mask[:1])
        shard = mediator_sharding(mesh)
        self._x = jax.device_put(jnp.asarray(xs), shard)
        self._y = jax.device_put(jnp.asarray(ys), shard)
        self._m = jax.device_put(jnp.asarray(mask), shard)
        self.last_placement_stats: dict | None = None

    def owner(self, cid: int) -> int:
        return cid // self._k_local

    def place(self, groups, m_pad):
        row_to_group, stats = scheduling.place_mediators(
            groups, self._n, m_pad // self._n, self.owner)
        self.last_placement_stats = stats
        return row_to_group

    @staticmethod
    def _group_positions(keys: np.ndarray, num_groups: int) -> np.ndarray:
        """Position of each element within its key's group, preserving the
        input (encounter) order inside every group -- the vectorized
        equivalent of walking the elements and bumping a per-key fill
        counter."""
        perm = np.argsort(keys, kind="stable")
        counts = np.bincount(keys, minlength=num_groups)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        pos = np.empty(keys.size, np.int64)
        pos[perm] = np.arange(keys.size) - np.repeat(starts, counts)
        return pos

    def plan(self, idx, slot):
        m_pad, gamma = idx.shape
        m_local = max(1, m_pad // self._n)
        # np.nonzero is row-major: identical visit order to the historical
        # ``for r, g in np.argwhere(slot > 0)`` loop, so first-encounter
        # dedup below fills serve lists in byte-identical order
        rr, gg = np.nonzero(slot > 0)
        cids = idx[rr, gg].astype(np.int64)
        owners = cids // self._k_local
        readers = rr // m_local
        remote = owners != readers
        loc = np.ones((m_pad, gamma), bool)       # inactive slots: local row 0
        lpos = np.zeros((m_pad, gamma), np.int32)
        rpos = np.zeros((m_pad, gamma), np.int32)
        lpos[rr[~remote], gg[~remote]] = \
            (cids[~remote] % self._k_local).astype(np.int32)
        loc[rr[remote], gg[remote]] = False
        if self.exchange == "gather":
            plan_args, occupied, capacity = self._plan_gather(
                m_pad, gamma, rr, gg, cids, remote, loc, lpos, rpos)
        else:
            plan_args, occupied, capacity = self._plan_ragged(
                m_pad, gamma, m_local, rr, gg, cids, owners, readers, remote,
                loc, lpos, rpos)
        slice_nb = getattr(self, "_slice_nbytes", 0)
        if self.exchange == "gather":
            # all_gather ships every shard's full padded serve buffer to
            # the (n - 1) other devices, occupied or not
            self.exchange_bytes_per_round = \
                capacity * (self._n - 1) * slice_nb
        else:
            # a ragged collective ships exactly the occupied pair slots
            self.exchange_bytes_per_round = occupied * slice_nb
        if self.last_placement_stats is not None:
            self.last_placement_stats["serve_capacity"] = int(capacity)
            self.last_placement_stats["serve_occupied"] = int(occupied)
            self.last_placement_stats["exchange"] = self.exchange
        return (self._x, self._y, self._m), plan_args

    def _plan_gather(self, m_pad, gamma, rr, gg, cids, remote, loc, lpos,
                     rpos):
        """Globally-deduplicated serve lists for the fixed-capacity
        all_gather; byte-identical to the historical interpreter loop."""
        f = max(1, min(m_pad * gamma, self._k_local))
        serve = np.zeros((self._n, f), np.int32)
        rc = cids[remote]
        occupied = 0
        if rc.size:
            uq, first, inv = np.unique(rc, return_index=True,
                                       return_inverse=True)
            enc = np.argsort(first, kind="stable")    # first-encounter order
            u_cid = uq[enc]
            u_own = u_cid // self._k_local
            j = self._group_positions(u_own, self._n)  # per-owner fill order
            serve[u_own, j] = (u_cid % self._k_local).astype(np.int32)
            enc_rank = np.empty(uq.size, np.int64)
            enc_rank[enc] = np.arange(uq.size)
            pos = u_own * f + j                        # rpos = owner*F + fill
            rpos[rr[remote], gg[remote]] = pos[enc_rank[inv]].astype(np.int32)
            occupied = int(uq.size)
        return ((jnp.asarray(serve), jnp.asarray(loc), jnp.asarray(lpos),
                 jnp.asarray(rpos)), occupied, self._n * f)

    def _plan_ragged(self, m_pad, gamma, m_local, rr, gg, cids, owners,
                     readers, remote, loc, lpos, rpos):
        """Per-(owner, reader)-pair send lists for the ppermute ring.

        A slice is deduplicated per *pair* (a cid read on two reader
        shards ships to both -- that is what "only to the rows that read
        it" costs) and lands in the reader's receive buffer at
        ``(hop - 1) * R + pair_fill``, which is what ``rpos`` indexes.
        """
        n = self._n
        r_cap = max(1, min(m_local * gamma, self._k_local))
        send = np.zeros((n, max(n - 1, 1), r_cap), np.int32)
        rc = cids[remote]
        occupied = 0
        if rc.size:
            k_pad = n * self._k_local
            code = (owners[remote] * n + readers[remote]) * k_pad + rc
            uq, first, inv = np.unique(code, return_index=True,
                                       return_inverse=True)
            enc = np.argsort(first, kind="stable")
            u_code = uq[enc]
            u_pair = u_code // k_pad
            u_cid = u_code % k_pad
            u_own = u_pair // n
            u_hop = (u_pair % n - u_own) % n           # reader = owner + hop
            j = self._group_positions(u_pair, n * n)
            if int(j.max(initial=-1)) >= r_cap:        # cannot happen: a
                raise AssertionError(                  # pair holds <= R cids
                    "ragged pair capacity overflow (internal invariant)")
            send[u_own, u_hop - 1, j] = (u_cid % self._k_local).astype(np.int32)
            enc_rank = np.empty(uq.size, np.int64)
            enc_rank[enc] = np.arange(uq.size)
            pos = (u_hop - 1) * r_cap + j              # reader-local rpos
            rpos[rr[remote], gg[remote]] = pos[enc_rank[inv]].astype(np.int32)
            occupied = int(uq.size)
        return ((jnp.asarray(send), jnp.asarray(loc), jnp.asarray(lpos),
                 jnp.asarray(rpos)), occupied, n * max(n - 1, 1) * r_cap)

    def slot_data(self, data, plan):
        if self.exchange == "gather":
            serve, loc, lpos, rpos = plan
            srv = serve.reshape(-1)               # this device's (F,) serve list

            def pick(shard):
                gathered = jax.lax.all_gather(shard[srv], "mediator", tiled=True)
                local = shard[lpos]               # (M_local, gamma, pad, ...)
                remote = gathered[rpos]
                sel = loc.reshape(loc.shape + (1,) * (local.ndim - 2))
                return jnp.where(sel, local, remote)

            return tuple(pick(a) for a in data)

        send, loc, lpos, rpos = plan
        n = self._n
        sidx = send[0]                            # this device's (n-1, R) lists

        def pick(shard):
            local = shard[lpos]
            if n == 1:                            # no remote slots exist
                return local
            # hop s: shard o ships its (o -> o+s) pair list to shard o+s;
            # the receive buffer concatenates hops in order, matching the
            # plan-side rpos layout (hop-1)*R + pair_fill
            chunks = [jax.lax.ppermute(shard[sidx[s - 1]], "mediator",
                                       ring_permutation(n, s))
                      for s in range(1, n)]
            remote = jnp.concatenate(chunks, axis=0)[rpos]
            sel = loc.reshape(loc.shape + (1,) * (local.ndim - 2))
            return jnp.where(sel, local, remote)

        return tuple(pick(a) for a in data)

    def per_device_bytes(self) -> int:
        return _bytes(self._x, self._y, self._m) // self._n

    # stats(): the unified base-class schema already surfaces
    # exchange/exchange_bytes_per_round from this class's attributes


class HostStore(ClientStore):
    """Host-RAM federation; per-schedule slices streamed to device.

    The packed store never leaves the host. Each reschedule device_puts
    the <= ``U_cap`` *unique* scheduled clients (padded to the static
    capacity so shapes, and hence the compiled round, are stable) and
    remaps the gather indices into that compact buffer -- the round then
    runs exactly like the replicated store over the small slice. The
    remap is ``np.searchsorted`` over the sorted uniques: O(c log c) per
    reschedule, independent of K (the historical dense ``(K,)`` remap
    array cost O(K) host time/memory per reschedule even when the
    schedule touched c << K clients).

    Streaming traffic is surfaced (``stats()["streamed_bytes"]``) and
    reported to the engine through ``last_stream_bytes`` so every
    host->device copy lands on the CommMeter's intra-pod ledger.
    """

    policy = "host"
    data_specs = (P(), P(), P())
    plan_specs = (P("mediator"),)

    def __init__(self, xs, ys, mask, mesh, capacity, *, source=None):
        self._src = source if source is not None else PackedClients(xs, ys, mask)
        self._cap = max(1, min(self._src.num_clients, capacity))
        self._rep = replicated_sharding(mesh)
        self._streamed_bytes = 0
        self.num_streams = 0

    def _staged_rows(self, uniq: np.ndarray) -> tuple:
        """Host staging buffers, padded to ``U_cap`` rows (the spill tier
        overrides this with its cache/prefetch path)."""
        return self._fetch_rows(uniq)

    def _fetch_rows(self, uniq: np.ndarray) -> tuple:
        out = tuple(np.zeros((self._cap,) + shape, dtype)
                    for shape, dtype in self._src.row_specs)
        if uniq.size:
            for buf, rows in zip(out, self._src.rows(uniq)):
                buf[:uniq.size] = rows
        return out

    def plan(self, idx, slot):
        uniq = np.unique(idx[slot > 0])
        if uniq.size > self._cap:
            raise ValueError(f"schedule touches {uniq.size} unique clients; "
                             f"{self.policy} store capacity is {self._cap}")
        # compact remap via binary search over the sorted uniques -- every
        # active slot's cid is in uniq by construction; inactive slots are
        # masked to row 0 (the historical dense-remap output, byte for byte)
        idx_c = np.where(slot > 0, np.searchsorted(uniq, idx), 0).astype(np.int32)
        data = tuple(jax.device_put(jnp.asarray(b), self._rep)
                     for b in self._staged_rows(uniq))
        moved = _bytes(*data)
        self._streamed_bytes += moved
        self.last_stream_bytes = moved
        self.num_streams += 1
        return data, (jnp.asarray(idx_c),)

    slot_data = ReplicatedStore.slot_data

    def per_device_bytes(self) -> int:
        return self._cap * self._src.nbytes_per_client

    # stats(): streamed_bytes/num_streams ride the unified base schema


class _RowLRU:
    """Fixed-capacity per-client-id row cache with LRU eviction.

    Rows live in preallocated host buffers; lookups and inserts are fully
    vectorized (argsort/searchsorted over the resident ids), so staging
    cost scales with the schedule, never with the cache. MAIN-THREAD
    ONLY: the spill store's prefetch workers never touch the cache --
    cached rows are copied out *before* a background stage is scheduled
    -- so no lock is needed and eviction can never race a reader.
    """

    def __init__(self, rows: int, specs):
        self.capacity = int(rows)
        n = max(self.capacity, 1)
        self._bufs = tuple(np.zeros((n,) + shape, dtype)
                           for shape, dtype in specs)
        self._ids = np.full(n, -1, np.int64)      # -1 = empty slot
        self._last_used = np.zeros(n, np.int64)
        self._tick = 0
        self.evictions = 0

    def lookup(self, uniq: np.ndarray, out: tuple) -> np.ndarray:
        """Copy cached rows for ``uniq`` into the staging buffers ``out``
        (capacity-padded, position-aligned with ``uniq``); returns the
        boolean hit mask. Hits get their recency bumped."""
        if self.capacity == 0 or uniq.size == 0:
            return np.zeros(uniq.size, bool)
        order = np.argsort(self._ids, kind="stable")
        sorted_ids = self._ids[order]
        pos = np.minimum(np.searchsorted(sorted_ids, uniq),
                         sorted_ids.size - 1)
        hit = sorted_ids[pos] == uniq
        slots = order[pos[hit]]
        where = np.flatnonzero(hit)
        for buf, cbuf in zip(out, self._bufs):
            buf[where] = cbuf[slots]
        self._tick += 1
        self._last_used[slots] = self._tick
        return hit

    def insert(self, ids: np.ndarray, rows: tuple) -> None:
        """Insert rows for ``ids`` (unique), evicting least-recently-used
        entries; ids already resident are skipped (a deep prefetch
        pipeline can stage the same client twice before either stage is
        consumed -- same bytes, so dropping the duplicate is free)."""
        if self.capacity == 0 or ids.size == 0:
            return
        fresh = np.flatnonzero(~np.isin(ids, self._ids))
        n = min(fresh.size, self.capacity)
        if n == 0:
            return
        fresh = fresh[:n]
        victims = np.argsort(self._last_used, kind="stable")[:n]
        self.evictions += int((self._ids[victims] >= 0).sum())
        self._ids[victims] = ids[fresh]
        self._tick += 1
        self._last_used[victims] = self._tick
        for cbuf, rbuf in zip(self._bufs, rows):
            cbuf[victims] = rbuf[fresh]


class SpilledHostStore(HostStore):
    """Disk/mmap-tier federation with an LRU row cache + pipelined prefetch.

    The ``host`` streaming contract, minus the host-RAM federation: rows
    come from a spill tier (``MmapClients``, or any lazy row source such
    as ``StreamingFederation``). Two mechanisms keep the stream off the
    round's critical path:

    * **LRU row cache**: ``lru_rows`` client rows (default ``2 * U_cap``,
      deliberately larger than one schedule) are kept in host RAM keyed
      by client id; clients reused by a later schedule are copied from
      RAM instead of re-read from the tier, and the least-recently-used
      rows are evicted on overflow (``stats()["lru_evictions"]``). This
      generalizes the historical one-generation cache (the previous
      staged buffers): reuse now survives an intervening schedule.
    * **Pipelined prefetch**: ``prefetch(ids)`` stages a *future*
      reschedule's unique clients on a daemon thread, and up to
      ``prefetch_depth`` stages may be in flight at once -- the engine
      fills the queue with its pre-drawn selections so the tier reads of
      the next N reschedules overlap device compute (one reschedule of
      lookahead stalls overlapped async waves, which burn through
      schedules faster than a disk tier streams them). Cached rows are
      copied out synchronously at ``prefetch`` call time (main thread);
      only the tier reads run on the worker, so the LRU needs no lock.
      ``plan`` consumes stages strictly in FIFO order: the front stage is
      joined and used when its ids match, and a mismatched stage is
      discarded (counted in ``prefetch_misses``) with a synchronous
      fallback through the same fetch path -- so prefetched and
      synchronous streams are bit-identical (asserted in tests).
    """

    policy = "spilled"

    def __init__(self, xs, ys, mask, mesh, capacity, *, source=None,
                 spill_dir: str | None = None, prefetch_depth: int = 1,
                 lru_rows: int | None = None):
        if source is None:
            source = MmapClients(xs, ys, mask, spill_dir)
        super().__init__(None, None, None, mesh, capacity, source=source)
        if prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        if lru_rows is not None and lru_rows < 0:
            raise ValueError("lru_rows must be >= 0")
        self.prefetch_depth = int(prefetch_depth)
        self.lru_rows = int(lru_rows) if lru_rows is not None \
            else 2 * self._cap
        self._lru = _RowLRU(self.lru_rows, self._src.row_specs)
        # FIFO of background stages: (thread, uniq, box, bufs, hits, miss)
        self._prefetched: deque = deque()
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.cache_hit_rows = 0
        self.tier_rows = 0

    @property
    def lru_evictions(self) -> int:
        return self._lru.evictions

    def _stage(self, uniq: np.ndarray) -> tuple:
        """Allocate staging buffers and serve the LRU hits (main thread).
        Returns ``(bufs, cached_rows, miss_positions)`` -- the tier reads
        for ``miss_positions`` are the caller's (sync or worker)."""
        bufs = tuple(np.zeros((self._cap,) + shape, dtype)
                     for shape, dtype in self._src.row_specs)
        hit = self._lru.lookup(uniq, bufs)
        return bufs, int(hit.sum()), np.flatnonzero(~hit)

    def _read_tier(self, uniq: np.ndarray, bufs: tuple,
                   miss: np.ndarray) -> None:
        if miss.size:
            for buf, rows in zip(bufs, self._src.rows(uniq[miss])):
                buf[miss] = rows

    def prefetch(self, ids: np.ndarray) -> None:
        """Queue a background stage of a future reschedule's clients."""
        uniq = np.unique(np.asarray(ids))
        if uniq.size > self._cap:
            return                        # plan() will raise; nothing to stage
        bufs, cached, miss = self._stage(uniq)
        box: dict = {}

        def work():
            self._read_tier(uniq, bufs, miss)
            box["done"] = True

        thread = threading.Thread(target=work, daemon=True,
                                  name="astraea-spill-prefetch")
        thread.start()
        self._prefetched.append((thread, uniq, box, bufs, cached, miss))

    def _join_inflight(self):
        for entry in self._prefetched:
            entry[0].join()

    def _staged_rows(self, uniq: np.ndarray) -> tuple:
        staged = None
        while self._prefetched and staged is None:
            thread, pre_uniq, box, bufs, cached, miss = \
                self._prefetched.popleft()
            thread.join()
            if box.get("done") and np.array_equal(pre_uniq, uniq):
                staged = (bufs, cached, miss)
                self.prefetch_hits += 1
                self.telemetry.instant("store_prefetch", hit=True,
                                       rows=int(uniq.size))
            else:
                self.prefetch_misses += 1
                self.telemetry.instant("store_prefetch", hit=False,
                                       rows=int(uniq.size))
        if staged is None:
            bufs, cached, miss = self._stage(uniq)
            self._read_tier(uniq, bufs, miss)
            staged = (bufs, cached, miss)
        bufs, cached, miss = staged
        self.cache_hit_rows += cached
        self.tier_rows += int(miss.size)
        if miss.size:                     # tier reads feed the LRU
            self._lru.insert(uniq[miss], tuple(b[miss] for b in bufs))
        return bufs

    # stats(): prefetch/cache/tier/LRU counters and spill_dir ride the
    # unified base schema


def build_client_store(policy: str, xs=None, ys=None, mask=None, mesh=None, *,
                       capacity: int | None = None, exchange: str = "ragged",
                       spill_dir: str | None = None, source=None,
                       prefetch_depth: int = 1, lru_rows: int | None = None,
                       telemetry=None) -> ClientStore:
    """Build the packed client store under ``policy`` (see module docstring).

    ``xs/ys/mask`` are the packed host arrays; the streaming policies
    (``host``/``spilled``) alternatively accept ``source``, a row source
    (``PackedClients``/``MmapClients``/``StreamingFederation``-like) that
    is never materialized as one array -- the million-client path.
    ``prefetch_depth``/``lru_rows`` tune the spilled store's streaming
    pipeline (ignored elsewhere; ``lru_rows=None`` = twice the capacity).
    ``telemetry`` optionally installs an ``obs.Telemetry`` handle (the
    adopting engine overwrites it with its own; default = no-op stubs).
    """
    if source is not None and policy not in ("host", "spilled"):
        raise ValueError(f"client-store policy {policy!r} needs the packed "
                         "arrays; streaming row sources require the 'host' "
                         "or 'spilled' policy")
    if policy == "replicated":
        store = ReplicatedStore(xs, ys, mask, mesh)
    elif policy == "sharded":
        store = ShardedStore(xs, ys, mask, mesh, exchange=exchange)
    elif policy in ("host", "spilled"):
        if capacity is None:
            capacity = source.num_clients if source is not None else xs.shape[0]
        if policy == "host":
            store = HostStore(xs, ys, mask, mesh, capacity, source=source)
        else:
            store = SpilledHostStore(xs, ys, mask, mesh, capacity,
                                     source=source, spill_dir=spill_dir,
                                     prefetch_depth=prefetch_depth,
                                     lru_rows=lru_rows)
    else:
        raise ValueError(f"unknown client-store policy {policy!r}; "
                         f"expected one of {POLICIES}")
    if telemetry is not None:
        from repro.obs.telemetry import as_telemetry
        store.telemetry = as_telemetry(telemetry)
    return store
