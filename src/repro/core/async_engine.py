"""Bounded-staleness async round subsystem: waves, commits, stragglers.

``FLRoundEngine.run_round`` is a synchronous barrier -- the slowest
mediator gates every synchronization round, which is exactly the
heterogeneous-edge pathology the paper discusses (§VII). This module wraps
the engine so mediator groups complete in **waves** and the server overlaps
aggregation with the stragglers' training under a bounded staleness ``S``.

Simulation model (everything deterministic, no wall-clock in the math):

* A ``StragglerModel`` (``core/staleness.py``) assigns each mediator slot a
  seeded slowdown factor; a mediator's simulated duration is
  ``factor * active_client_slots * E_m``.
* ``scheduling.partition_waves`` sorts mediators by duration and chunks
  them into waves of ``wave_size`` -- slow mediators are co-scheduled into
  the late waves so the fast waves are never blocked.
* All waves of round ``r`` are dispatched at the round's virtual start
  ``T_r`` from the same params snapshot, and complete at
  ``T_r + max(duration in wave)``.
* The server performs **one commit per round** at virtual time
  ``C_r = max(completion of every wave that is >= S rounds old,
  completion of round r's fastest wave)`` and folds every wave that has
  landed by then. ``T_{r+1} = C_r``: the next round dispatches from the
  committed weights while older stragglers may still be in flight. A wave
  dispatched in round ``q`` therefore folds with staleness
  ``s = r - q <= S`` -- the bound is enforced by construction, because a
  commit always waits for waves that would otherwise exceed it.

Staleness-discounted aggregation (the Eq. 6 generalization; discount
policies in ``core/staleness.py``)::

    w~_m        = lambda(s_m) * n_m,      s_m = r - q_m
    params_{r+1} = params_r + sum_m (w~_m / sum_m' w~_m') * delta_m^(q_m)

where ``delta_m^(q)`` is mediator ``m``'s weight delta computed from the
round-``q`` dispatch snapshot, ``n_m`` its sample count, and ``lambda`` is
``constant`` (1), ``polynomial`` ((1+s)^-alpha) or ``exponential``
(e^(-alpha s)). The FedAvg (``aggregate="weights"``) path replaces
``params_r + sum ... delta`` with the discounted weighted average of the
returned weights. Every policy returns exactly 1.0 at ``s = 0``.

``S = 0`` **reproduces the synchronous engine bitwise**: the commit must
wait for every wave of its own round, so all contributions fold together
with ``lambda = 1``; the fold reassembles the full padded-M stack in
schedule order (real mediators first, dummy rows last -- identical bits,
because each wave runs the engine's one traced program with non-members
slot-masked into exact no-ops) and applies the same Eq. 6 reduction. This
is asserted, on 1 and 4 forced host devices, in
``tests/test_async_engine.py``.

Online augmentation: a wave runs the engine's one traced program, so the
in-round resample+warp (``core/augmentation.online_augment_batch``) rides
along unchanged.  The augmentation keys fork off the engine's round-indexed
``_round_keys`` stream per mediator row -- never off wave membership -- so
a mediator draws the same augmentations whichever wave executes it, and
S=0 stays bitwise-identical to the synchronous engine with augmentation
enabled (``num_round_traces`` stays 1 across waves too; asserted in
tests/test_online_aug.py).

Execution note: each wave executes the full padded-M program with
non-member rows masked, trading simulator FLOPs for trace stability
(``num_round_traces == 1`` across waves and reschedules) and bit-fidelity.
Real overlapped dispatch on a multi-controller TPU would instead launch
per-wave collectives -- that follow-up is tracked in ROADMAP.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduling
from repro.core.engine import FLRoundEngine
from repro.core.fl import evaluate
from repro.core.staleness import (StragglerModel, StragglerSpec,
                                  make_staleness_policy)

PyTree = Any


@dataclass(frozen=True)
class AsyncSpec:
    """Async round configuration surfaced through both trainers.

    ``staleness_bound`` is ``S``; ``wave_size`` is mediators per wave
    (``0`` = single wave, i.e. the synchronous barrier); ``straggler``
    drives the simulated fleet; ``policy``/``policy_alpha`` pick the
    staleness discount ``lambda``.
    """
    staleness_bound: int = 0
    wave_size: int = 0
    straggler: StragglerSpec = field(default_factory=StragglerSpec)
    policy: str = "polynomial"
    policy_alpha: float = 0.5

    def __post_init__(self):
        if self.staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        make_staleness_policy(self.policy, self.policy_alpha)  # validates


@dataclass
class _PendingWave:
    """One executed-but-uncommitted wave's contribution."""
    round: int
    wave: int
    t_done: float
    rows: np.ndarray            # schedule indices, sorted ascending
    values: PyTree              # (n_rows, ...) stacked deltas / weights
    weights: jax.Array          # (n_rows,) Eq. 6 sample counts


class AsyncRoundEngine:
    """Bounded-staleness wave executor wrapping an ``FLRoundEngine``.

    The wrapped engine keeps owning params, store, schedule and comm
    meter; this class owns the virtual clock, the wave buffer, and the
    staleness-discounted commits (see module docstring).
    """

    def __init__(self, engine: FLRoundEngine, spec: AsyncSpec):
        self.engine, self.spec = engine, spec
        self.policy = make_staleness_policy(spec.policy, spec.policy_alpha)
        self._parallel_clients = engine.cfg.aggregate == "weights"

        # the commit MUST be jitted: compiled as one program it is
        # bitwise-identical to the aggregation tail inside the engine's
        # round executable, while eager op-by-op dispatch rounds
        # differently on some inputs (jit caches one executable per
        # distinct commit size -- S=0 always commits the full padded M).
        # On a 2-D (mediator, model) mesh the commit mirrors the engine's
        # §8 cycle: gather the model-sharded params, fold the replicated
        # wave stack, reshard on the way out -- exact-byte moves, so the
        # 2-D async trajectory stays bitwise too.
        # Under LoRA the committed state is the replicated adapter dict and
        # the fold is sharding-free; engine._fold is the ONE fold tail
        # shared with the sync round, which is what keeps S=0 bitwise.
        def _commit(state, stacked, weights):
            agg = self.engine._aggregate(stacked, weights)
            return self.engine._fold(state, agg)

        self._commit_fn = jax.jit(_commit)
        self._straggler: StragglerModel | None = None
        self._pending: list[_PendingWave] = []
        self._dummy: tuple | None = None    # current round's dummy-row tail
        self.virtual_time = 0.0             # async clock (commit times)
        self.sync_time = 0.0                # barrier baseline on same fleet
        self.num_commits = 0
        self.commit_log: list[dict] = []
        self.last_wave_stats: dict | None = None
        self.history: list[dict] = []
        self._round = 0

    # ---- trainer-facing surface, delegated to the wrapped engine ----
    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, value):
        self.engine.params = value

    @property
    def comm(self):
        return self.engine.comm

    @property
    def telemetry(self):
        """The wrapped engine's observability handle (obs/): one handle
        per engine, shared by both the sync and async drivers."""
        return self.engine.telemetry

    @property
    def sim_speedup(self) -> float:
        """Simulated round-time reduction vs the synchronous barrier."""
        return self.sync_time / max(self.virtual_time, 1e-12)

    # ------------------------------------------------------------------
    # one virtual synchronization round: dispatch waves, commit
    # ------------------------------------------------------------------
    def run_round(self) -> None:
        spec, eng = self.spec, self.engine
        tel = eng.telemetry
        wan0 = eng.comm.total_bytes
        round_span = tel.span("round", round=self._round, mode="async",
                              staleness_bound=spec.staleness_bound,
                              wave_size=spec.wave_size,
                              policy=eng.cfg.store)
        with round_span as rsp:
            self._run_round_body(spec, eng, tel)
            rsp.set(wan_bytes=eng.comm.total_bytes - wan0,
                    traces=eng.num_round_traces)
        tel.observe_async_round(self, duration_s=rsp.duration_s)

    def _run_round_body(self, spec, eng, tel) -> None:
        data_args, plan_args, unperm, slot, row_to_group, m_real = \
            eng.ensure_schedule()
        slot_np = np.asarray(slot)
        m_pad = slot_np.shape[0]
        rtg = np.asarray(row_to_group)
        row_of = np.zeros(m_real, np.int64)
        for rr, g in enumerate(rtg):
            if g >= 0:
                row_of[g] = rr
        if self._straggler is None:
            # sized to the REAL mediator count (stable: Alg. 3 and the
            # random schedule both emit ceil(c/gamma) groups), so the
            # configured straggler fraction is never diluted by dummy
            # padding slots; durations() raises if a schedule ever grows
            self._straggler = StragglerModel(spec.straggler, m_real)
        em = max(1, eng.cfg.mediator_epochs)
        work = slot_np[row_of].sum(axis=1) * em             # (m_real,)
        durations = self._straggler.durations(work)
        waves, wstats = scheduling.partition_waves(durations, spec.wave_size)
        self.last_wave_stats = wstats

        r = self._round
        t0 = self.virtual_time
        keys = eng._round_keys(rtg, m_real, round_idx=r)
        snapshot = eng.server_state         # dispatch snapshot for round r
        for wi, wave in enumerate(waves):
            rows = np.sort(np.asarray(wave, np.int64))
            wave_span = tel.span("wave", wave=wi, round=r,
                                 mediators=int(rows.size),
                                 sim_done=float(t0 + wstats["wave_times"][wi]))
            with wave_span as wsp:
                mask = np.zeros((m_pad, 1), np.float32)
                mask[row_of[rows]] = 1.0
                wslot = slot * jnp.asarray(mask)  # members bitwise, rest 0
                stacked, weights = eng.wave_fn(snapshot, data_args,
                                               plan_args, unperm, wslot,
                                               keys, *eng.extra_args())
                rj = jnp.asarray(rows)
                vals = jax.tree.map(lambda a: a[rj], stacked)
                wts = weights[rj]
                wsp.sync_on((vals, wts))
                if wi == 0:
                    # dummy-row tail (weight exactly 0) completing the
                    # padded stack so an S=0 commit aggregates the byte-
                    # identical input of the synchronous round executable
                    dj = jnp.arange(m_real, m_pad)
                    self._dummy = (jax.tree.map(lambda a: a[dj], stacked),
                                   weights[dj])
                clients = int(slot_np[row_of[rows]].sum())
                wave_wan0 = eng.comm.total_bytes
                if self._parallel_clients:
                    eng.comm.fedavg_wave(clients)
                else:
                    eng.comm.astraea_wave(clients, len(rows),
                                          eng.cfg.mediator_epochs)
                if eng._model_size > 1 and not eng._tp_rows:
                    # every gather-oracle wave execution gathers the
                    # model-sharded weights (wave_fn's _prep: the params
                    # snapshot, or the LoRA backbone operand) -- one
                    # intra-pod charge per wave, unlike the WAN ledger
                    # where waves only re-partition a round's fixed total.
                    # TP-rows waves never gather.
                    eng.comm.model_axis_round(eng._msize * eng._model_size,
                                              eng._model_size)
                if eng.store.exchange_bytes_per_round:
                    # each wave runs the full padded-M program, so the
                    # sharded serve exchange rides the interconnect per wave
                    eng.comm.store_exchange(
                        eng.store.exchange_bytes_per_round)
                self._pending.append(_PendingWave(
                    r, wi, t0 + wstats["wave_times"][wi], rows, vals, wts))
                wsp.set(clients=clients,
                        wan_bytes=eng.comm.total_bytes - wave_wan0)
        eng.comm.end_round()

        # ---- commit C_r: wait for staleness-expired waves + the round's
        # fastest wave, fold everything that has landed by then ----
        s_bound = spec.staleness_bound
        due = [p.t_done for p in self._pending if p.round <= r - s_bound]
        c_time = max(due + [t0 + wstats["wave_times"][0]])
        ready = [p for p in self._pending if p.t_done <= c_time]
        self._pending = [p for p in self._pending if p.t_done > c_time]
        self._fold(ready, r, c_time)
        self.virtual_time = c_time
        self.sync_time += wstats["barrier_time"]
        self._round += 1
        eng._round = self._round

    def _fold(self, ready: list[_PendingWave], r: int, c_time: float) -> None:
        """One server commit: staleness-discounted Eq. 6 over ``ready``."""
        assert ready, "a commit always folds at least the round's fast wave"
        with self.telemetry.span("commit", round=r,
                                 sim_time=float(c_time)) as csp:
            self._fold_traced(ready, r, c_time, csp)

    def _fold_traced(self, ready, r, c_time, csp) -> None:
        parts_v, parts_w, stales = [], [], []
        for q in sorted({p.round for p in ready}):
            ws = [p for p in ready if p.round == q]
            rows = np.concatenate([p.rows for p in ws])
            order = jnp.asarray(np.argsort(rows, kind="stable"))
            vals = jax.tree.map(lambda *xs: jnp.concatenate(xs)[order],
                                *[p.values for p in ws])
            wts = jnp.concatenate([p.weights for p in ws])[order]
            s = r - q
            if s > 0:       # s == 0 keeps the weights bitwise untouched
                wts = wts * jnp.float32(self.policy(s))
            parts_v.append(vals)
            parts_w.append(wts)
            stales.extend([s] * rows.size)
        dvals, dwts = self._dummy
        stack = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                             *(parts_v + [dvals]))
        wvec = jnp.concatenate(parts_w + [dwts])
        if self.engine._model_size > 1 and self.engine._lora_mapping is None:
            # the jitted commit gathers the model-sharded params too; the
            # LoRA commit folds replicated adapters (no gather)
            self.engine.comm.model_axis_round(
                self.engine._msize * self.engine._model_size,
                self.engine._model_size)
        self.engine.server_state = self._commit_fn(self.engine.server_state,
                                                   stack, wvec)
        self.num_commits += 1
        self.commit_log.append({
            "round": r, "time": float(c_time),
            "folded_rows": int(sum(p.rows.size for p in ready)),
            "staleness": stales,
            "pending_after": len(self._pending),
        })
        csp.set(folded_rows=self.commit_log[-1]["folded_rows"],
                staleness_max=max(stales) if stales else 0,
                pending_after=len(self._pending))
        csp.sync_on(self.engine.server_state)

    def flush(self) -> None:
        """Fold every still-pending straggler wave (end of training).

        Pending waves are at most ``S`` rounds behind by construction, so
        the final fold discounts them by ``s = r_final - q <= S``.
        """
        if not self._pending:
            return
        c_time = max(p.t_done for p in self._pending)
        ready, self._pending = self._pending, []
        self._fold(ready, self._round, c_time)
        self.virtual_time = max(self.virtual_time, c_time)
        # the flush commit lands after the last round's absorption: emit
        # one final post-flush metrics snapshot so its staleness
        # observations reach the registry too
        self.telemetry.observe_async_round(self)

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        eng = self.engine
        for i in range(rounds):
            last = i == rounds - 1      # robust to repeated fit() calls
            self.run_round()
            if last:
                self.flush()
            if self._round % eval_every == 0 or last:
                m = evaluate(eng.model, eng.merged_params(),
                             eng.data.test_images, eng.data.test_labels)
                stales = [s for c in self.commit_log for s in c["staleness"]]
                m.update(round=self._round, traffic_mb=eng.comm.megabytes,
                         sim_time=self.virtual_time,
                         sync_sim_time=self.sync_time,
                         sim_speedup=self.sim_speedup,
                         commits=self.num_commits,
                         staleness_mean=float(np.mean(stales)) if stales
                         else 0.0,
                         staleness_max=int(max(stales)) if stales else 0)
                if eng.last_schedule_stats and \
                        "kld_mean" in eng.last_schedule_stats:
                    m["mediator_kld_mean"] = \
                        eng.last_schedule_stats["kld_mean"]
                self.history.append(m)
        return self.history
