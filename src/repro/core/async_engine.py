"""Bounded-staleness async round subsystem: waves, commits, stragglers.

``FLRoundEngine.run_round`` is a synchronous barrier -- the slowest
mediator gates every synchronization round, which is exactly the
heterogeneous-edge pathology the paper discusses (§VII). This module wraps
the engine so mediator groups complete in **waves** and the server overlaps
aggregation with the stragglers' training under a bounded staleness ``S``.

Simulation model (everything deterministic, no wall-clock in the math):

* A ``StragglerModel`` (``core/staleness.py``) assigns seeded slowdown
  factors at one of two granularities: per mediator *slot* (historical;
  duration = ``factor * active_client_slots * E_m``) or per *client*
  (``StragglerSpec(level="client")``; a mediator trains its members
  sequentially, so duration = ``E_m * sum(factor_c)`` over the group --
  a slow device drags whichever mediator Alg. 3 packs it into).
* ``scheduling.partition_waves`` sorts mediators by duration and chunks
  them into waves of ``wave_size`` -- slow mediators/clients are
  co-scheduled into the late waves so the fast waves are never blocked.
* All waves of round ``r`` are dispatched at the round's virtual start
  ``T_r`` from the same params snapshot, and complete at
  ``T_r + max(duration in wave)``.
* The server performs **one commit per round** at virtual time
  ``C_r = max(completion of every wave that is >= S rounds old,
  completion of round r's fastest wave)`` and folds every wave that has
  landed by then. ``T_{r+1} = C_r``: the next round dispatches from the
  committed weights while older stragglers may still be in flight. A wave
  dispatched in round ``q`` therefore folds with staleness
  ``s = r - q <= S`` -- the bound is enforced by construction, because a
  commit always waits for waves that would otherwise exceed it.
* ``S`` is either the fixed ``staleness_bound`` knob or, with
  ``AsyncSpec.adaptive`` set, derived per round from the *observed*
  commit-lag distribution: an ``AdaptiveStaleness`` EWMA over per-wave
  lags (in rounds, on this virtual clock), clamped to ``[s_min, s_max]``.
  A constant lag stream keeps the EWMA bitwise fixed, so the adaptive
  trajectory reproduces the fixed-S one exactly (property-tested).

Staleness-discounted aggregation (the Eq. 6 generalization; discount
policies in ``core/staleness.py``)::

    w~_m        = lambda(s_m) * n_m,      s_m = r - q_m
    params_{r+1} = params_r + sum_m (w~_m / sum_m' w~_m') * delta_m^(q_m)

where ``delta_m^(q)`` is mediator ``m``'s weight delta computed from the
round-``q`` dispatch snapshot, ``n_m`` its sample count, and ``lambda`` is
``constant`` (1), ``polynomial`` ((1+s)^-alpha) or ``exponential``
(e^(-alpha s)). The FedAvg (``aggregate="weights"``) path replaces
``params_r + sum ... delta`` with the discounted weighted average of the
returned weights. Every policy returns exactly 1.0 at ``s = 0``.

``S = 0`` **reproduces the synchronous engine bitwise**: the commit must
wait for every wave of its own round, so all contributions fold together
with ``lambda = 1``; the fold reassembles the full padded-M stack in
schedule order (real mediators first, dummy rows last) and applies the
same jitted Eq. 6 + fold tail the sync round uses (``engine._fold``).
This is asserted, on 1 and 4 forced host devices, across all three
dispatchable stores, in ``tests/test_async_engine.py`` and
``tests/test_async_overlap.py``.

Dispatch modes (``AsyncSpec.dispatch``; the pipeline contract is
documented in ``src/repro/core/README.md``):

* ``"masked"`` (historical default): every wave executes the engine's one
  full padded-M ``wave_fn`` with non-member slot rows zeroed (exact
  no-ops, like dummy mediators) -- one trace serves every wave of every
  reschedule, but a W-wave round costs W x the sync round's row compute
  and the host may sit between waves. ``block_each_wave=True`` adds an
  explicit host block after each wave: the *blocking baseline* the wall
  -clock benchmarks compare against.
* ``"overlapped"``: each wave runs a **sliced** executable
  (``engine.wave_fn_for(width)``) over just its own schedule rows padded
  to the mediator mesh size -- a W-wave round costs ~1x the sync row
  compute -- and the host never blocks between waves or commits: wave
  k+1's mediators are enqueued (and, with JAX async dispatch, training)
  while wave k's contributions and the round's commit are still in
  flight. Commits become a pipelined fold; the only host sync points are
  ``synchronize()`` at eval/checkpoint boundaries and ``flush()``.
  ``overlap_frac`` reports how often a dispatch found the previous
  wave's result still in flight (``jax.Array.is_ready`` probe). The
  commit donates its input state buffer (when the engine donates), which
  is safe exactly because every consumer of snapshot ``r`` is enqueued
  before commit ``r``. Row-permuting stores (``sharded``) route gathers
  by row position and cannot be sliced: overlapped mode keeps the
  pipelined commits but falls back to masked execution per wave.

  Bitwise note: sliced waves feed each row through a batch-width-
  dependent program under the default ``row_exec="vmap"``; the S=0
  bitwise-vs-sync guarantee for overlapped dispatch therefore requires
  ``row_exec="map"`` (the batch-size-invariant row program). Masked
  dispatch preserves the historical guarantee under every config.

Multi-process execution: pass a ``launch/mesh.py::ProcessWaveDispatcher``
to shard waves across ``jax.distributed`` processes -- each process
executes the waves it owns (round-robin) on its process-local mesh and
exchanges the wave payloads host-side through the coordination-service
KV store (cross-process XLA collectives are not available on the CPU
backend). Every process performs every commit, so server states stay
bitwise identical, and every process books the full comm charges, so the
WAN ledger is process-count-invariant (asserted by
``benchmarks/distributed_smoke.py``).

Online augmentation: a wave runs the engine's row program, so the
in-round resample+warp (``core/augmentation.online_augment_batch``) rides
along unchanged.  The augmentation keys fork off the engine's round-indexed
``_round_keys`` stream per mediator row -- never off wave membership -- so
a mediator draws the same augmentations whichever wave executes it, and
S=0 stays bitwise-identical to the synchronous engine with augmentation
enabled (``num_round_traces`` stays 1 across waves too; asserted in
tests/test_online_aug.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduling
from repro.core.engine import FLRoundEngine
from repro.core.fl import evaluate
from repro.core.staleness import (AdaptiveStaleness, AdaptiveStalenessSpec,
                                  StragglerModel, StragglerSpec,
                                  make_staleness_policy)

PyTree = Any

DISPATCH_MODES = ("masked", "overlapped")


@dataclass(frozen=True)
class AsyncSpec:
    """Async round configuration surfaced through both trainers.

    ``staleness_bound`` is the fixed ``S`` (ignored when ``adaptive`` is
    set); ``wave_size`` is mediators per wave (``0`` = single wave, i.e.
    the synchronous barrier); ``straggler`` drives the simulated fleet
    (mediator- or client-level); ``policy``/``policy_alpha`` pick the
    staleness discount ``lambda``. ``dispatch`` selects masked full-M or
    overlapped sliced execution (module docstring); ``block_each_wave``
    turns the masked loop into the blocking wall-clock baseline (host
    blocks on every wave's result) and is incompatible with overlapped
    dispatch. ``adaptive`` switches ``S`` to the EWMA commit-lag
    controller (``core/staleness.py::AdaptiveStaleness``).
    """
    staleness_bound: int = 0
    wave_size: int = 0
    straggler: StragglerSpec = field(default_factory=StragglerSpec)
    policy: str = "polynomial"
    policy_alpha: float = 0.5
    dispatch: str = "masked"
    block_each_wave: bool = False
    adaptive: AdaptiveStalenessSpec | None = None

    def __post_init__(self):
        if self.staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(f"unknown dispatch mode {self.dispatch!r}; "
                             f"expected one of {DISPATCH_MODES}")
        if self.block_each_wave and self.dispatch == "overlapped":
            raise ValueError("block_each_wave is the blocking baseline; it "
                             "contradicts overlapped dispatch")
        make_staleness_policy(self.policy, self.policy_alpha)  # validates


@dataclass
class _PendingWave:
    """One executed-but-uncommitted wave's contribution."""
    round: int
    wave: int
    t_done: float
    rows: np.ndarray            # schedule indices, sorted ascending
    values: PyTree              # (n_rows, ...) stacked deltas / weights
    weights: jax.Array          # (n_rows,) Eq. 6 sample counts


class AsyncRoundEngine:
    """Bounded-staleness wave executor wrapping an ``FLRoundEngine``.

    The wrapped engine keeps owning params, store, schedule and comm
    meter; this class owns the virtual clock, the wave buffer, the
    dispatch pipeline, and the staleness-discounted commits (see module
    docstring).
    """

    def __init__(self, engine: FLRoundEngine, spec: AsyncSpec, *,
                 dispatcher=None):
        self.engine, self.spec = engine, spec
        self.policy = make_staleness_policy(spec.policy, spec.policy_alpha)
        self._parallel_clients = engine.cfg.aggregate == "weights"
        # dispatch resolution: overlapped mode pipelines the host loop
        # always, and slices wave executables when the store's rows are
        # position-independent (sharded routes gathers by row position,
        # so it keeps masked per-wave execution under the pipeline)
        self._pipelined = spec.dispatch == "overlapped"
        self._sliced = self._pipelined and not engine.store.permutes_rows
        self._dispatcher = dispatcher

        # the commit MUST be jitted: compiled as one program it is
        # bitwise-identical to the aggregation tail inside the engine's
        # round executable, while eager op-by-op dispatch rounds
        # differently on some inputs (jit caches one executable per
        # distinct commit size -- S=0 always commits the full padded M).
        # On a 2-D (mediator, model) mesh the commit mirrors the engine's
        # §8 cycle: gather the model-sharded params, fold the replicated
        # wave stack, reshard on the way out -- exact-byte moves, so the
        # 2-D async trajectory stays bitwise too.
        # Under LoRA the committed state is the replicated adapter dict and
        # the fold is sharding-free; engine._fold is the ONE fold tail
        # shared with the sync round, which is what keeps S=0 bitwise.
        def _commit(state, stacked, weights):
            agg = self.engine._aggregate(stacked, weights)
            return self.engine._fold(state, agg)

        # pipelined commits donate the input state like the sync round
        # does: every consumer of snapshot r (round r's waves) is enqueued
        # before commit r, so the donation can never invalidate an
        # in-flight read. Masked mode keeps the historical no-donation
        # commit (callers may hold pre-commit state references).
        donate = (0,) if (self._pipelined and engine.cfg.donate_params) \
            else ()
        self._commit_fn = jax.jit(_commit, donate_argnums=donate)
        self._straggler: StragglerModel | None = None
        self._adaptive = AdaptiveStaleness(spec.adaptive) \
            if spec.adaptive is not None else None
        self._pending: list[_PendingWave] = []
        self._dummy: tuple | None = None    # current round's dummy-row tail
        self._plan_cache: tuple | None = None   # (plan_args id, host copies)
        self.virtual_time = 0.0             # async clock (commit times)
        self.sync_time = 0.0                # barrier baseline on same fleet
        self.num_commits = 0
        self.commit_log: list[dict] = []
        self.last_wave_stats: dict | None = None
        self.history: list[dict] = []
        # dispatch-pipeline observability (never enters the math):
        # a dispatch counts as overlapped when the previously dispatched
        # wave's result was still in flight at dispatch time
        self.num_dispatches = 0
        self.num_overlapped_dispatches = 0
        self._overlap_checks = 0
        self._last_probe: jax.Array | None = None
        self.wall_commit_wait_s = 0.0       # host time spent in synchronize()
        self.num_syncs = 0
        self._round = 0

    # ---- trainer-facing surface, delegated to the wrapped engine ----
    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, value):
        self.engine.params = value

    @property
    def comm(self):
        return self.engine.comm

    @property
    def telemetry(self):
        """The wrapped engine's observability handle (obs/): one handle
        per engine, shared by both the sync and async drivers."""
        return self.engine.telemetry

    @property
    def sim_speedup(self) -> float:
        """Simulated round-time reduction vs the synchronous barrier.
        Exactly 1.0 before any round has committed (both clocks sit at
        zero; the historical 0/eps division reported a nonsense 0x)."""
        if self.num_commits == 0:
            return 1.0
        return self.sync_time / max(self.virtual_time, 1e-12)

    @property
    def staleness_bound(self) -> int:
        """The bound governing the next commit: the adaptive controller's
        clamped EWMA bound when configured, else the fixed spec knob."""
        if self._adaptive is not None:
            return self._adaptive.bound
        return self.spec.staleness_bound

    @property
    def overlap_frac(self) -> float:
        """Fraction of wave dispatches issued while the previous wave's
        result was still in flight (``is_ready`` probe at dispatch time).
        0.0 under the blocking baseline by construction."""
        if self._overlap_checks == 0:
            return 0.0
        return self.num_overlapped_dispatches / self._overlap_checks

    # ------------------------------------------------------------------
    # one virtual synchronization round: dispatch waves, commit
    # ------------------------------------------------------------------
    def run_round(self) -> None:
        spec, eng = self.spec, self.engine
        tel = eng.telemetry
        wan0 = eng.comm.total_bytes
        round_span = tel.span("round", round=self._round, mode="async",
                              dispatch=spec.dispatch,
                              staleness_bound=self.staleness_bound,
                              wave_size=spec.wave_size,
                              policy=eng.cfg.store)
        with round_span as rsp:
            self._run_round_body(spec, eng, tel)
            rsp.set(wan_bytes=eng.comm.total_bytes - wan0,
                    traces=eng.num_round_traces)
        tel.observe_async_round(self, duration_s=rsp.duration_s)

    def _durations(self, eng, spec, slot_np, row_of, m_real) -> np.ndarray:
        if self._straggler is None:
            # sized to the REAL population (mediator level: Alg. 3 and the
            # random schedule both emit a stable ceil(c/gamma) groups;
            # client level: the whole federation), so the configured
            # straggler fraction is never diluted by dummy padding slots
            self._straggler = StragglerModel(
                spec.straggler, m_real,
                num_clients=eng.data.num_clients
                if spec.straggler.level == "client" else None)
        em = max(1, eng.cfg.mediator_epochs)
        if spec.straggler.level == "client":
            return self._straggler.durations_for_groups(eng.last_groups, em)
        work = slot_np[row_of].sum(axis=1) * em             # (m_real,)
        return self._straggler.durations(work)

    def _run_round_body(self, spec, eng, tel) -> None:
        data_args, plan_args, unperm, slot, row_to_group, m_real = \
            eng.ensure_schedule()
        slot_np = np.asarray(slot)
        m_pad = slot_np.shape[0]
        rtg = np.asarray(row_to_group)
        row_of = np.zeros(m_real, np.int64)
        for rr, g in enumerate(rtg):
            if g >= 0:
                row_of[g] = rr
        durations = self._durations(eng, spec, slot_np, row_of, m_real)
        waves, wstats = scheduling.partition_waves(durations, spec.wave_size)
        self.last_wave_stats = wstats

        r = self._round
        t0 = self.virtual_time
        keys = eng._round_keys(rtg, m_real, round_idx=r)
        if self._sliced:
            # host copies of the schedule tensors the slices are cut from
            # (plan reuses the cache until the engine repacks; keys are
            # per-round). Tiny arrays -- (M_pad, gamma) ints.
            if self._plan_cache is None or self._plan_cache[0] is not plan_args:
                self._plan_cache = (plan_args,
                                    tuple(np.asarray(a) for a in plan_args))
            plan_np = self._plan_cache[1]
            keys_np = np.asarray(keys)
        snapshot = eng.server_state         # dispatch snapshot for round r
        for wi, wave in enumerate(waves):
            rows = np.sort(np.asarray(wave, np.int64))
            wave_span = tel.span("wave", wave=wi, round=r,
                                 mediators=int(rows.size),
                                 sim_done=float(t0 + wstats["wave_times"][wi]))
            with wave_span as wsp:
                overlapped_now = self._probe_overlap()
                owner_here = self._dispatcher is None or \
                    self._dispatcher.owner_of(r, wi) == \
                    self._dispatcher.process_index
                need_dummy = wi == 0
                if owner_here:
                    with tel.span("dispatch_gap", wave=wi, round=r,
                                  overlapped=overlapped_now):
                        if self._sliced:
                            vals, wts, dummy = self._dispatch_sliced(
                                eng, snapshot, data_args, plan_np, slot_np,
                                keys_np, row_of[rows], m_real, m_pad,
                                need_dummy)
                        else:
                            vals, wts, dummy = self._dispatch_masked(
                                eng, snapshot, data_args, plan_args, unperm,
                                slot, keys, rows, row_of, m_real, m_pad,
                                need_dummy)
                    if self._dispatcher is not None:
                        self._publish_wave(r, wi, vals, wts, dummy)
                else:
                    vals, wts, dummy = self._receive_wave(r, wi, need_dummy)
                if need_dummy:
                    self._dummy = dummy
                self._last_probe = wts
                if spec.block_each_wave:
                    # the blocking baseline: the host waits for every
                    # wave's result before dispatching the next
                    jax.block_until_ready((vals, wts))
                if not self._pipelined:
                    wsp.sync_on((vals, wts))
                clients = int(slot_np[row_of[rows]].sum())
                wave_wan0 = eng.comm.total_bytes
                # comm charges are schedule-derived and booked on EVERY
                # process of a multi-process run -- the WAN ledger is
                # dispatch-mode- and process-count-invariant
                if self._parallel_clients:
                    eng.comm.fedavg_wave(clients)
                else:
                    eng.comm.astraea_wave(clients, len(rows),
                                          eng.cfg.mediator_epochs)
                if eng._model_size > 1 and not eng._tp_rows:
                    # every gather-oracle wave execution gathers the
                    # model-sharded weights (wave_fn's _prep: the params
                    # snapshot, or the LoRA backbone operand) -- one
                    # intra-pod charge per wave, unlike the WAN ledger
                    # where waves only re-partition a round's fixed total.
                    # TP-rows waves never gather.
                    eng.comm.model_axis_round(eng._msize * eng._model_size,
                                              eng._model_size)
                if eng.store.exchange_bytes_per_round:
                    # masked waves run the full padded-M program, so the
                    # sharded serve exchange rides the interconnect per
                    # wave (sliced waves only exist for exchange-free
                    # stores: exchange_bytes_per_round == 0 there)
                    eng.comm.store_exchange(
                        eng.store.exchange_bytes_per_round)
                self._pending.append(_PendingWave(
                    r, wi, t0 + wstats["wave_times"][wi], rows, vals, wts))
                wsp.set(clients=clients,
                        wan_bytes=eng.comm.total_bytes - wave_wan0)
        eng.comm.end_round()

        # ---- commit C_r: wait for staleness-expired waves + the round's
        # fastest wave, fold everything that has landed by then ----
        s_bound = self.staleness_bound
        due = [p.t_done for p in self._pending if p.round <= r - s_bound]
        c_time = max(due + [t0 + wstats["wave_times"][0]])
        ready = [p for p in self._pending if p.t_done <= c_time]
        self._pending = [p for p in self._pending if p.t_done > c_time]
        if self._adaptive is not None:
            # feed the controller the lags this commit realized: folded
            # waves lag r - q rounds; still-pending waves will lag at
            # least one more. Virtual-clock quantities only.
            for p in ready:
                self._adaptive.observe(r - p.round)
            for p in self._pending:
                self._adaptive.observe(r - p.round + 1)
        self._fold(ready, r, c_time)
        self.virtual_time = c_time
        self.sync_time += wstats["barrier_time"]
        self._round += 1
        eng._round = self._round

    # ------------------------------------------------------------------
    # wave execution paths
    # ------------------------------------------------------------------
    def _probe_overlap(self) -> bool:
        """Non-blocking check whether the previously dispatched wave is
        still in flight (observability only -- never gates dispatch)."""
        self.num_dispatches += 1
        if self._last_probe is None:
            return False
        self._overlap_checks += 1
        try:
            in_flight = not self._last_probe.is_ready()
        except AttributeError:          # non-jax probe (received wave)
            in_flight = False
        if in_flight:
            self.num_overlapped_dispatches += 1
        return in_flight

    def _dispatch_masked(self, eng, snapshot, data_args, plan_args, unperm,
                         slot, keys, rows, row_of, m_real, m_pad, need_dummy):
        """Historical execution: the full padded-M ``wave_fn`` with
        non-member slot rows zeroed (exact no-ops)."""
        mask = np.zeros((m_pad, 1), np.float32)
        mask[row_of[rows]] = 1.0
        wslot = slot * jnp.asarray(mask)    # members bitwise, rest 0
        stacked, weights = eng.wave_fn(snapshot, data_args, plan_args,
                                       unperm, wslot, keys,
                                       *eng.extra_args())
        rj = jnp.asarray(rows)
        vals = jax.tree.map(lambda a: a[rj], stacked)
        wts = weights[rj]
        dummy = None
        if need_dummy:
            # dummy-row tail (weight exactly 0) completing the padded
            # stack so an S=0 commit aggregates the byte-identical input
            # of the synchronous round executable
            dj = jnp.arange(m_real, m_pad)
            dummy = (jax.tree.map(lambda a: a[dj], stacked), weights[dj])
        return vals, wts, dummy

    def _dispatch_sliced(self, eng, snapshot, data_args, plan_np, slot_np,
                         keys_np, pos, m_real, m_pad, need_dummy):
        """Overlapped execution: ``wave_fn_for(width)`` over just this
        wave's schedule rows, padded to the mediator mesh size with no-op
        rows (zero plan/slot/keys -- the exact bytes of the schedule's
        dummy rows, so padding outputs ARE dummy-row outputs).

        The round's dummy tail is rebuilt by broadcasting one no-op row's
        output: under ``row_exec="map"`` every no-op row of every width
        produces identical bits, so the commit stack matches the sync
        round's byte for byte (dummy weights are exactly 0 besides).
        """
        n = int(pos.size)
        msize = eng._msize
        width = -(-n // msize) * msize
        n_dummy = m_pad - m_real
        if need_dummy and n_dummy > 0 and width == n:
            width += msize      # guarantee a no-op row to clone the tail from

        def pad_rows(a_np):
            out = np.zeros((width,) + a_np.shape[1:], a_np.dtype)
            out[:n] = a_np[pos]
            return jnp.asarray(out)

        plan_w = tuple(pad_rows(a) for a in plan_np)
        slot_w = pad_rows(slot_np)
        keys_w = pad_rows(keys_np)
        unperm_w = jnp.arange(width, dtype=jnp.int32)
        stacked, weights = eng.wave_fn_for(width)(
            snapshot, data_args, plan_w, unperm_w, slot_w, keys_w,
            *eng.extra_args())
        vals = jax.tree.map(lambda a: a[:n], stacked)
        wts = weights[:n]
        dummy = None
        if need_dummy:
            dummy = (jax.tree.map(
                lambda a: jnp.broadcast_to(a[n], (n_dummy,) + a.shape[1:]),
                stacked), jnp.broadcast_to(weights[n], (n_dummy,))) \
                if n_dummy > 0 else \
                (jax.tree.map(lambda a: a[:0], stacked), weights[:0])
        return vals, wts, dummy

    # ------------------------------------------------------------------
    # multi-process wave exchange (launch/mesh.py::ProcessWaveDispatcher)
    # ------------------------------------------------------------------
    def _payload_treedef(self):
        return jax.tree.structure(self.engine.server_state)

    def _publish_wave(self, r, wi, vals, wts, dummy) -> None:
        """Ship an owned wave's contribution to the other processes
        (host-side KV exchange; forces materialization, which is the
        per-wave sync a multi-process run accepts in return for
        process-level parallelism)."""
        leaves = [np.asarray(x) for x in jax.tree.leaves(vals)]
        leaves.append(np.asarray(wts))
        if dummy is not None:
            leaves.extend(np.asarray(x) for x in jax.tree.leaves(dummy[0]))
            leaves.append(np.asarray(dummy[1]))
        self._dispatcher.publish(f"wave-{r}-{wi}", leaves)

    def _receive_wave(self, r, wi, expect_dummy):
        leaves = self._dispatcher.receive(f"wave-{r}-{wi}")
        tdef = self._payload_treedef()
        nv = tdef.num_leaves
        vals = jax.tree.unflatten(tdef,
                                  [jnp.asarray(a) for a in leaves[:nv]])
        wts = jnp.asarray(leaves[nv])
        dummy = None
        if expect_dummy:
            dvals = jax.tree.unflatten(
                tdef, [jnp.asarray(a) for a in leaves[nv + 1:2 * nv + 1]])
            dummy = (dvals, jnp.asarray(leaves[2 * nv + 1]))
        return vals, wts, dummy

    # ------------------------------------------------------------------
    # commits
    # ------------------------------------------------------------------
    def _fold(self, ready: list[_PendingWave], r: int, c_time: float) -> None:
        """One server commit: staleness-discounted Eq. 6 over ``ready``."""
        assert ready, "a commit always folds at least the round's fast wave"
        with self.telemetry.span("commit", round=r,
                                 sim_time=float(c_time)) as csp:
            self._fold_traced(ready, r, c_time, csp)

    def _fold_traced(self, ready, r, c_time, csp) -> None:
        parts_v, parts_w, stales = [], [], []
        for q in sorted({p.round for p in ready}):
            ws = [p for p in ready if p.round == q]
            rows = np.concatenate([p.rows for p in ws])
            order = jnp.asarray(np.argsort(rows, kind="stable"))
            vals = jax.tree.map(lambda *xs: jnp.concatenate(xs)[order],
                                *[p.values for p in ws])
            wts = jnp.concatenate([p.weights for p in ws])[order]
            s = r - q
            if s > 0:       # s == 0 keeps the weights bitwise untouched
                wts = wts * jnp.float32(self.policy(s))
            parts_v.append(vals)
            parts_w.append(wts)
            stales.extend([s] * rows.size)
        dvals, dwts = self._dummy
        stack = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                             *(parts_v + [dvals]))
        wvec = jnp.concatenate(parts_w + [dwts])
        if self.engine._model_size > 1 and self.engine._lora_mapping is None:
            # the jitted commit gathers the model-sharded params too; the
            # LoRA commit folds replicated adapters (no gather)
            self.engine.comm.model_axis_round(
                self.engine._msize * self.engine._model_size,
                self.engine._model_size)
        self.engine.server_state = self._commit_fn(self.engine.server_state,
                                                   stack, wvec)
        self.num_commits += 1
        self.commit_log.append({
            "round": r, "time": float(c_time),
            "folded_rows": int(sum(p.rows.size for p in ready)),
            "staleness": stales,
            "staleness_bound": self.staleness_bound,
            "pending_after": len(self._pending),
        })
        csp.set(folded_rows=self.commit_log[-1]["folded_rows"],
                staleness_max=max(stales) if stales else 0,
                pending_after=len(self._pending))
        if not self._pipelined:
            csp.sync_on(self.engine.server_state)

    def synchronize(self) -> float:
        """Drain the dispatch pipeline: block until the latest commit (and
        transitively every wave feeding it) has landed on device.

        The ONLY host sync point of overlapped dispatch -- ``fit`` calls
        it at eval boundaries and ``flush`` at the end of training.
        Returns the wall seconds spent waiting; purely observability
        (``commit_lag`` span + ``wall_commit_wait_s``), never part of the
        virtual-clock math."""
        t0 = time.perf_counter()
        with self.telemetry.span("commit_lag", round=self._round,
                                 pending=len(self._pending)) as sp:
            jax.block_until_ready(self.engine.server_state)
            waited = time.perf_counter() - t0
            sp.set(waited_s=waited)
        self.wall_commit_wait_s += waited
        self.num_syncs += 1
        return waited

    def flush(self) -> None:
        """Fold every still-pending straggler wave (end of training).

        Pending waves are at most ``S`` rounds behind by construction, so
        the final fold discounts them by ``s = r_final - q <= S``. A
        no-op (not an error) when nothing is pending -- including before
        any round has run.
        """
        if not self._pending:
            if self.num_commits:
                self.synchronize()
            return
        c_time = max(p.t_done for p in self._pending)
        ready, self._pending = self._pending, []
        self._fold(ready, self._round, c_time)
        self.virtual_time = max(self.virtual_time, c_time)
        self.synchronize()
        # the flush commit lands after the last round's absorption: emit
        # one final post-flush metrics snapshot so its staleness
        # observations reach the registry too
        self.telemetry.observe_async_round(self)

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        eng = self.engine
        for i in range(rounds):
            last = i == rounds - 1      # robust to repeated fit() calls
            self.run_round()
            if last:
                self.flush()
            if self._round % eval_every == 0 or last:
                self.synchronize()      # eval is a pipeline sync point
                m = evaluate(eng.model, eng.merged_params(),
                             eng.data.test_images, eng.data.test_labels)
                stales = [s for c in self.commit_log for s in c["staleness"]]
                m.update(round=self._round, traffic_mb=eng.comm.megabytes,
                         sim_time=self.virtual_time,
                         sync_sim_time=self.sync_time,
                         sim_speedup=self.sim_speedup,
                         commits=self.num_commits,
                         overlap_frac=self.overlap_frac,
                         staleness_bound=self.staleness_bound,
                         staleness_mean=float(np.mean(stales)) if stales
                         else 0.0,
                         staleness_max=int(max(stales)) if stales else 0)
                if eng.last_schedule_stats and \
                        "kld_mean" in eng.last_schedule_stats:
                    m["mediator_kld_mean"] = \
                        eng.last_schedule_stats["kld_mean"]
                self.history.append(m)
        return self.history
