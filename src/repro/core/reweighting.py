"""Cost-sensitive reweighting baseline (ablation partner for Astraea).

The paper's related work (§II-A) dismisses classical imbalanced-learning
remedies (SMOTE-style oversampling, boosting) as unsuitable for FL because
client data is private and distributed. One remedy it does NOT evaluate is
*loss reweighting*: the server knows the global label histogram (clients
already report it in the initialization phase), so it can broadcast
inverse-frequency class weights for the local loss -- zero extra
communication, zero extra storage.

We implement it as a drop-in FedAvg variant so EXPERIMENTS.md can compare:
  FedAvg < FedAvg+reweight < Astraea(aug) < Astraea(aug+mediators)
(the expected ordering: reweighting rebalances gradients but, unlike
Alg. 2, adds no new minority-class *information*, and unlike Alg. 3 leaves
local/client imbalance untouched).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedavg import FedAvgTrainer
from repro.core import fl as _fl
from repro.models.cnn import Model


def inverse_frequency_weights(global_counts: np.ndarray, *,
                              smoothing: float = 1.0,
                              normalize: bool = True) -> np.ndarray:
    """w_c = (n / C) / (count_c + smoothing), normalized to mean 1."""
    counts = np.asarray(global_counts, np.float64)
    w = (counts.sum() / len(counts)) / (counts + smoothing)
    if normalize:
        w = w * len(w) / w.sum()
    return w.astype(np.float32)


def weighted_cross_entropy(class_weights: jnp.ndarray):
    """Loss factory: per-sample weights looked up from the label."""

    def loss(logits, labels, mask=None):
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        w = class_weights[labels]
        if mask is not None:
            w = w * mask
        return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1e-6)

    return loss


@dataclass
class ReweightedFedAvgTrainer(FedAvgTrainer):
    """FedAvg whose local loss is inverse-frequency weighted by the global
    label distribution (server-computed, broadcast once)."""

    def __post_init__(self):
        counts = self.data.client_counts().sum(0)
        weights = jnp.asarray(inverse_frequency_weights(counts))
        wce = weighted_cross_entropy(weights)

        def loss_fn(model, params, x, y, mask, key):
            logits = model.apply(params, x, train=True, rngs=key)
            return wce(logits, y, mask)

        self.loss_fn = loss_fn
        super().__post_init__()
