"""Mediator update (Alg. 1, MediatorUpdate) as a jit'd scan.

Within one mediator the assigned clients train **sequentially** -- client
i+1 starts from client i's weights (the paper's "asynchronous SGD") -- for
``E_m`` mediator epochs; the mediator returns the weight *delta* relative
to the weights it received. Mediators themselves are vmapped by the server.

Mediators are padded to exactly ``gamma`` client slots; empty slots carry
all-zero masks and are provably no-ops (see core.fl docstring).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.fl import LocalSpec, make_client_update
from repro.models.cnn import Model
from repro.optim.optimizers import Optimizer

Array = jax.Array
PyTree = Any


def make_mediator_update(model: Model, opt: Optimizer, local: LocalSpec,
                         mediator_epochs: int,
                         loss_fn: Callable | None = None) -> Callable:
    """Returns ``mediator_update(params, xs, ys, masks, key) -> delta`` where
    ``xs/ys/masks`` carry a leading ``gamma`` client axis. ``loss_fn``
    replaces the default masked cross-entropy (see core.fl)."""
    client_update = make_client_update(model, opt, local, loss_fn=loss_fn)

    def mediator_update(params: PyTree, xs: Array, ys: Array, masks: Array,
                        key: Array) -> PyTree:
        start = params

        def client_body(w, inputs):
            x, y, m, k = inputs
            return client_update(w, x, y, m, k), None

        def epoch_body(w, ekey):
            gamma = xs.shape[0]
            keys = jax.random.split(ekey, gamma)
            w, _ = jax.lax.scan(client_body, w, (xs, ys, masks, keys))
            return w, None

        ekeys = jax.random.split(key, mediator_epochs)
        w, _ = jax.lax.scan(epoch_body, params, ekeys)
        return jax.tree.map(lambda a, b: a - b, w, start)

    return mediator_update
