"""FedAvg (McMahan et al. 2016) -- the paper's baseline.

Each communication round: sample ``c`` online clients, every selected client
trains E local epochs *in parallel* from the same global weights, the server
aggregates with weights n_k / n (Eq. 6).

FedAvg is the ``gamma=1`` + random-singleton-schedule + full-weight
aggregation configuration of ``core.engine.FLRoundEngine``; this class is a
thin wrapper presenting the historical trainer API.

``alpha`` enables the paper's "augmentation-only" ablation (Alg. 2 without
mediators): ``aug_mode="online"`` hands the plan to the round engine (the
device-resident resample+warp, zero extra storage), ``"materialized"``
rebuilds the federation up front like the historical Astraea phase.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import augmentation
from repro.core.engine import EngineConfig, FLRoundEngine
from repro.core.fl import LocalSpec
from repro.data.federated import FederatedDataset
from repro.models.cnn import Model
from repro.optim.optimizers import Optimizer


@dataclass
class FedAvgTrainer:
    model: Model
    opt: Optimizer
    data: FederatedDataset
    clients_per_round: int           # c
    local: LocalSpec                 # B, E
    alpha: float | None = None       # Alg. 2 factor; None = plain FedAvg
    aug_mode: str | None = "online"  # "online" | "materialized" | None
    # recompute the plan from each round's cohort histograms (see
    # AstraeaTrainer.adaptive_plan; FedAvg reschedules every round, so the
    # plan drifts with the per-round client sample)
    adaptive_plan: bool = False
    store: str = "replicated"        # client-store placement policy
    store_exchange: str = "ragged"   # sharded serve exchange mode
    # padded mediator count; defaults to c (gamma=1) so the per-round
    # random reschedule never re-jits the round executable
    pad_mediators_to: int | None = None
    # bounded-staleness async rounds (core/async_engine.py); None = the
    # synchronous barrier engine
    async_spec: object = None
    mesh: object = None              # mediator mesh; None = all devices
    # model-axis size of the 2-D (mediator, model) mesh (see
    # AstraeaTrainer.model_parallel). Ignored when ``mesh`` is given.
    model_parallel: int | None = None
    # §8 TP row compute / LoRA adapter exchange (see AstraeaTrainer)
    tp_rows: object = "auto"
    lora_rank: int | None = None
    lora_alpha: float | None = None
    # optional obs.Telemetry handle threaded into the engine (host-side
    # spans + metrics; None = the zero-cost no-op stubs)
    telemetry: object = None
    seed: int = 0
    loss_fn: object = None           # optional custom local loss
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        # ---- Rebalancing phase (Alg. 2), shared with AstraeaTrainer ----
        phase = augmentation.resolve_aug_mode(self.data, self.alpha,
                                              self.aug_mode, self.seed)
        self.data = phase.data
        self.augmentation_plan = phase.plan
        self.extra_storage_frac = phase.extra_storage_frac
        self.planned_extra_frac = phase.planned_extra_frac
        engine_plan, adaptive_alpha = augmentation.resolve_engine_plan(
            phase, self.adaptive_plan, self.alpha)
        from repro.launch.mesh import resolve_fl_mesh
        mesh = resolve_fl_mesh(self.mesh, self.model_parallel)
        # donate_params=False: see AstraeaTrainer -- historical callers may
        # hold references to trainer.params across rounds
        pad_m = self.pad_mediators_to or \
            min(self.clients_per_round, self.data.num_clients)
        self.engine = FLRoundEngine(
            self.model, self.opt, self.data,
            EngineConfig.fedavg(clients_per_round=self.clients_per_round,
                                local=self.local, store=self.store,
                                store_exchange=self.store_exchange,
                                pad_mediators_to=pad_m, tp_rows=self.tp_rows,
                                lora_rank=self.lora_rank,
                                lora_alpha=self.lora_alpha,
                                donate_params=False, seed=self.seed),
            mesh=mesh, loss_fn=self.loss_fn,
            aug_plan=engine_plan, adaptive_aug_alpha=adaptive_alpha,
            telemetry=self.telemetry)
        if phase.mode == "materialized":
            self.engine.comm.plan_broadcast(self.data.num_classes,
                                            self.data.num_clients)
        if self.async_spec is not None:
            from repro.core.async_engine import AsyncRoundEngine
            self.runner = AsyncRoundEngine(self.engine, self.async_spec)
        else:
            self.runner = self.engine
        self.history = self.runner.history

    # ---- historical trainer surface, delegated to the engine ----
    @property
    def params(self):
        return self.engine.params

    @params.setter
    def params(self, value):
        self.engine.params = value

    @property
    def comm(self):
        return self.engine.comm

    @property
    def _round(self):
        return self.engine._round

    @_round.setter
    def _round(self, value):
        self.engine._round = value

    def run_round(self) -> None:
        self.runner.run_round()

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        return self.runner.fit(rounds, eval_every)
