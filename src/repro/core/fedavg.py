"""FedAvg (McMahan et al. 2016) -- the paper's baseline.

Each communication round: sample ``c`` online clients, every selected client
trains E local epochs *in parallel* from the same global weights, the server
aggregates with weights n_k / n (Eq. 6). Selected clients are vmapped -- one
XLA program per federation shape.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl import LocalSpec, make_client_update, weighted_average, evaluate
from repro.core.comm import CommMeter
from repro.data.federated import FederatedDataset
from repro.models.cnn import Model, count_params
from repro.optim.optimizers import Optimizer

PyTree = Any


def _pad_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass
class FedAvgTrainer:
    model: Model
    opt: Optimizer
    data: FederatedDataset
    clients_per_round: int           # c
    local: LocalSpec                 # B, E
    seed: int = 0
    loss_fn: object = None           # optional custom local loss
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        sizes = [x.shape[0] for x in self.data.client_images]
        pad = _pad_multiple(max(sizes), self.local.batch_size)
        self._x, self._y, self._mask = self.data.padded(pad)
        self._sizes = self._mask.sum(axis=1)
        self._rng = np.random.default_rng(self.seed)
        self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self.comm = CommMeter(count_params(self.params))
        client_update = make_client_update(self.model, self.opt, self.local,
                                           loss_fn=self.loss_fn)

        @jax.jit
        def round_fn(params, xs, ys, masks, keys):
            ws = jax.vmap(client_update, in_axes=(None, 0, 0, 0, 0))(
                params, xs, ys, masks, keys)
            weights = masks.sum(axis=(1,))
            return weighted_average(ws, weights)

        self._round_fn = round_fn
        self._round = 0

    def run_round(self) -> None:
        c = min(self.clients_per_round, self.data.num_clients)
        sel = self._rng.choice(self.data.num_clients, size=c, replace=False)
        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), self._round), c)
        self.params = self._round_fn(
            self.params, jnp.asarray(self._x[sel]), jnp.asarray(self._y[sel]),
            jnp.asarray(self._mask[sel]), keys)
        self.comm.fedavg_round(c)
        self._round += 1

    def fit(self, rounds: int, eval_every: int = 10) -> list[dict]:
        for _ in range(rounds):
            self.run_round()
            if self._round % eval_every == 0 or self._round == rounds:
                m = evaluate(self.model, self.params,
                             self.data.test_images, self.data.test_labels)
                m.update(round=self._round, traffic_mb=self.comm.megabytes)
                self.history.append(m)
        return self.history
