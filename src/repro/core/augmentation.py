"""Algorithm 2 — Global data distribution based data augmentation.

Server side: from the global per-class counts ``C_1..C_N`` compute the mean
``C_bar``; every class with ``C_i < C_bar`` goes into the augmentation set,
and each of its samples generates ``round((C_bar / C_y) ** alpha)``
augmentations (random shift, rotation, shear, zoom).

Client side: augmentation runs *locally and in parallel* on each client --
no raw data leaves a device. We implement the four augmentation primitives
as a single random affine warp (bilinear resampling via
``jax.scipy.ndimage.map_coordinates``), which is the JAX-native equivalent
of the Keras ImageDataGenerator the paper used.

The paper's key subtlety, which we preserve exactly: the augmentation count
is a *function of the class's global count*, so a large ``alpha`` (e.g. 2)
overshoots ``C_bar`` for very-minority classes and re-imbalances the data --
EXPERIMENTS.md reproduces that failure mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distribution as dist

Array = jax.Array


# --------------------------------------------------------------------------
# Server-side plan (Alg. 2 lines 1-6)
# --------------------------------------------------------------------------

def augmentation_plan(global_counts: np.ndarray, alpha: float) -> np.ndarray:
    """Per-class number of augmentations per existing sample.

    Returns an int array ``(num_classes,)``: 0 for classes at/above the mean
    (not in the augmentation set), else ``round((C_bar / C_i) ** alpha)``.
    """
    counts = np.asarray(global_counts, np.float64)
    c_bar = counts.mean()
    with np.errstate(divide="ignore"):
        factor = np.where(counts > 0, (c_bar / np.maximum(counts, 1.0)) ** alpha, 0.0)
    n_aug = np.rint(factor).astype(np.int64)
    n_aug[counts >= c_bar] = 0
    return n_aug


def planned_counts(global_counts: np.ndarray, alpha: float) -> np.ndarray:
    """Post-augmentation expected global counts (used by tests + EXPERIMENTS)."""
    counts = np.asarray(global_counts, np.float64)
    return counts * (1 + augmentation_plan(counts, alpha))


# --------------------------------------------------------------------------
# Client-side augmentation primitives (Alg. 2 line 11, ``Augment``)
# --------------------------------------------------------------------------

def _affine_params(key: Array, *, shift: float, rot: float, shear: float, zoom: float):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    theta = jax.random.uniform(k1, (), minval=-rot, maxval=rot)
    sh = jax.random.uniform(k2, (), minval=-shear, maxval=shear)
    zx = 1.0 + jax.random.uniform(k3, (), minval=-zoom, maxval=zoom)
    zy = 1.0 + jax.random.uniform(k4, (), minval=-zoom, maxval=zoom)
    tx, ty = jax.random.uniform(k5, (2,), minval=-shift, maxval=shift)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    # inverse mapping: output grid -> input coords (rotation ∘ shear ∘ zoom)
    mat = jnp.array([[cos / zx, (sin + sh) / zx], [(-sin) / zy, cos / zy]])
    return mat, jnp.array([tx, ty])


@partial(jax.jit, static_argnames=("order",))
def random_affine(key: Array, image: Array, *, shift: float = 3.0, rot: float = 0.3,
                  shear: float = 0.2, zoom: float = 0.15, order: int = 1) -> Array:
    """One random shift+rotation+shear+zoom warp of an ``(H, W, C)`` image."""
    h, w, c = image.shape
    mat, trans = _affine_params(key, shift=shift, rot=rot, shear=shear, zoom=zoom)
    yy, xx = jnp.meshgrid(jnp.arange(h, dtype=jnp.float32),
                          jnp.arange(w, dtype=jnp.float32), indexing="ij")
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    coords = jnp.stack([yy - cy, xx - cx])                       # (2, H, W)
    src = jnp.tensordot(mat, coords, axes=1)                     # (2, H, W)
    src_y = src[0] + cy + trans[0]
    src_x = src[1] + cx + trans[1]

    def warp_channel(ch):
        return jax.scipy.ndimage.map_coordinates(ch, [src_y, src_x], order=order, mode="constant")

    return jnp.stack([warp_channel(image[..., i]) for i in range(c)], axis=-1)


def augment_batch(key: Array, images: Array, n_copies: int, **kw) -> Array:
    """``n_copies`` independent warps of each image: ``(n, H, W, C)`` ->
    ``(n * n_copies, H, W, C)``."""
    n = images.shape[0]
    keys = jax.random.split(key, n * n_copies).reshape(n_copies, n, -1)
    out = jax.vmap(lambda ks: jax.vmap(lambda k, im: random_affine(k, im, **kw))(ks, images))(keys)
    return out.reshape((n * n_copies,) + images.shape[1:])


# --------------------------------------------------------------------------
# Full client rebalance (Alg. 2 lines 8-13) -- numpy orchestration around
# jit'd warps, because ragged per-class growth is inherently dynamic-shape.
# --------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


@partial(jax.jit, static_argnames=("shift", "rot", "shear", "zoom", "order"))
def _warp_many(key: Array, images: Array, *, shift=3.0, rot=0.3, shear=0.2,
               zoom=0.15, order=1) -> Array:
    keys = jax.random.split(key, images.shape[0])
    return jax.vmap(lambda k, im: random_affine(
        k, im, shift=shift, rot=rot, shear=shear, zoom=zoom, order=order))(keys, images)


def rebalance_client(key: Array, images: np.ndarray, labels: np.ndarray,
                     n_aug_per_class: np.ndarray, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Apply the server's plan to one client's local dataset.

    Returns the client's dataset with augmentations appended and shuffled
    (Alg. 2 line 13). All of the client's augmentations run as ONE jit'd
    warp over a power-of-two padded stack, so XLA's compile cache is hit
    across clients (a >10x init speedup vs per-class calls).
    """
    images = np.asarray(images)
    labels = np.asarray(labels)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    n_aug = np.asarray(n_aug_per_class)[labels]          # copies per sample
    reps = np.repeat(np.arange(labels.shape[0]), n_aug)  # source index per augmentation
    if reps.size == 0:
        perm = rng.permutation(images.shape[0])
        return images[perm], labels[perm]
    total_pad = _next_pow2(reps.size)
    reps_pad = np.concatenate([reps, rng.choice(reps, total_pad - reps.size)]) \
        if total_pad != reps.size else reps
    aug = np.asarray(_warp_many(key, jnp.asarray(images[reps_pad]), **kw))[:reps.size]
    out_x = np.concatenate([images, aug])
    out_y = np.concatenate([labels, labels[reps]])
    perm = rng.permutation(out_x.shape[0])
    return out_x[perm], out_y[perm]


def rebalance_federation(key: Array, client_images: list[np.ndarray],
                         client_labels: list[np.ndarray], num_classes: int,
                         alpha: float, **kw):
    """End-to-end Alg. 2 over a federation.

    Returns (new_client_images, new_client_labels, plan, extra_storage_frac).
    """
    counts = np.zeros(num_classes)
    for y in client_labels:
        counts += np.bincount(y, minlength=num_classes)
    plan = augmentation_plan(counts, alpha)
    out_x, out_y = [], []
    for i, (x, y) in enumerate(zip(client_images, client_labels)):
        cx, cy = rebalance_client(jax.random.fold_in(key, i), x, y, plan, **kw)
        out_x.append(cx)
        out_y.append(cy)
    before = sum(x.shape[0] for x in client_images)
    after = sum(x.shape[0] for x in out_x)
    return out_x, out_y, plan, (after - before) / max(before, 1)
