"""Algorithm 2 — Global data distribution based data augmentation.

Server side: from the global per-class counts ``C_1..C_N`` compute the mean
``C_bar``; every class with ``C_i < C_bar`` goes into the augmentation set,
and each of its samples generates ``round((C_bar / C_y) ** alpha)``
augmentations (random shift, rotation, shear, zoom).

Client side: augmentation runs *locally and in parallel* on each client --
no raw data leaves a device. We implement the four augmentation primitives
as a single random affine warp (bilinear resampling via
``jax.scipy.ndimage.map_coordinates``), which is the JAX-native equivalent
of the Keras ImageDataGenerator the paper used.

The paper's key subtlety, which we preserve exactly: the augmentation count
is a *function of the class's global count*, so a large ``alpha`` (e.g. 2)
overshoots ``C_bar`` for very-minority classes and re-imbalances the data --
EXPERIMENTS.md reproduces that failure mode.

Two execution modes share the plan math:

* **Materialized** (``rebalance_federation``) -- the historical pre-training
  phase: every augmented copy is generated up front into host numpy and the
  federation is rebuilt.  Faithful to the paper's deployment (clients store
  their augmentations, the ~24% extra-storage cost of Fig. 9) and kept as
  the equivalence oracle for the online mode.
* **Online** (``online_augment_batch``) -- the device-resident pipeline:
  nothing is materialized; each round the jitted round program redraws a
  fixed-shape, class-conditional resample+warp of every scheduled client's
  padded batch.  Each output slot draws its source sample from a seeded
  categorical with per-sample weights ``mask * (1 + n_aug[y])`` and is then
  warped with probability ``n_aug[y] / (1 + n_aug[y])`` -- so the expected
  class mixture of the draws is exactly ``planned_counts`` (normalized) and
  the expected raw-vs-warped composition matches Alg. 2's ``C_y`` originals
  + ``C_y * n_aug_y`` copies, while every shape stays static (one round
  trace).  Stores keep the *raw* clients: per-device bytes fall back to the
  pre-augmentation packed size.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distribution as dist

Array = jax.Array


# --------------------------------------------------------------------------
# Server-side plan (Alg. 2 lines 1-6)
# --------------------------------------------------------------------------

def augmentation_plan(global_counts: np.ndarray, alpha: float) -> np.ndarray:
    """Per-class number of augmentations per existing sample.

    Returns an int array ``(num_classes,)``: 0 for classes at/above the mean
    (not in the augmentation set), else ``round((C_bar / C_i) ** alpha)``.

    Alg. 2 line 3 edge case, handled explicitly: a class with ``C_i == 0``
    is *below* the mean (it enters the augmentation set) but there is no
    sample to warp, so its plan entry is 0 by construction -- not by the
    accident of a division guard.  ``C_bar`` still averages over ALL
    classes, empty ones included, exactly as the paper's line 1 does.
    """
    counts = np.asarray(global_counts, np.float64)
    if counts.ndim != 1:
        raise ValueError(f"global_counts must be 1-D, got shape {counts.shape}")
    c_bar = counts.mean()
    n_aug = np.zeros(counts.shape, np.int64)
    # the augmentation set: minority classes that actually have samples --
    # an empty class contributes nothing to warp (explicit, tested)
    grow = (counts > 0) & (counts < c_bar)
    n_aug[grow] = np.rint((c_bar / counts[grow]) ** alpha).astype(np.int64)
    return n_aug


def planned_counts(global_counts: np.ndarray, alpha: float) -> np.ndarray:
    """Post-augmentation expected global counts (used by tests + EXPERIMENTS)."""
    counts = np.asarray(global_counts, np.float64)
    return counts * (1 + augmentation_plan(counts, alpha))


def online_mixture(global_counts: np.ndarray, alpha: float) -> np.ndarray:
    """Expected class distribution of ONE online draw from data with
    ``global_counts``: exactly ``planned_counts`` normalized to 1 (each draw
    picks sample ``i`` with probability proportional to ``1 + n_aug[y_i]``)."""
    planned = planned_counts(global_counts, alpha)
    return planned / max(planned.sum(), 1.0)


# --------------------------------------------------------------------------
# Client-side augmentation primitives (Alg. 2 line 11, ``Augment``)
# --------------------------------------------------------------------------

def _affine_params(key: Array, *, shift: float, rot: float, shear: float, zoom: float):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    theta = jax.random.uniform(k1, (), minval=-rot, maxval=rot)
    sh = jax.random.uniform(k2, (), minval=-shear, maxval=shear)
    zx = 1.0 + jax.random.uniform(k3, (), minval=-zoom, maxval=zoom)
    zy = 1.0 + jax.random.uniform(k4, (), minval=-zoom, maxval=zoom)
    tx, ty = jax.random.uniform(k5, (2,), minval=-shift, maxval=shift)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    # inverse mapping: output grid -> input coords (rotation ∘ shear ∘ zoom)
    mat = jnp.array([[cos / zx, (sin + sh) / zx], [(-sin) / zy, cos / zy]])
    return mat, jnp.array([tx, ty])


@partial(jax.jit, static_argnames=("order",))
def random_affine(key: Array, image: Array, *, shift: float = 3.0, rot: float = 0.3,
                  shear: float = 0.2, zoom: float = 0.15, order: int = 1) -> Array:
    """One random shift+rotation+shear+zoom warp of an ``(H, W, C)`` image."""
    h, w, c = image.shape
    mat, trans = _affine_params(key, shift=shift, rot=rot, shear=shear, zoom=zoom)
    yy, xx = jnp.meshgrid(jnp.arange(h, dtype=jnp.float32),
                          jnp.arange(w, dtype=jnp.float32), indexing="ij")
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    coords = jnp.stack([yy - cy, xx - cx])                       # (2, H, W)
    src = jnp.tensordot(mat, coords, axes=1)                     # (2, H, W)
    src_y = src[0] + cy + trans[0]
    src_x = src[1] + cx + trans[1]

    def warp_channel(ch):
        return jax.scipy.ndimage.map_coordinates(ch, [src_y, src_x], order=order, mode="constant")

    return jnp.stack([warp_channel(image[..., i]) for i in range(c)], axis=-1)


def augment_batch(key: Array, images: Array, n_copies: int, **kw) -> Array:
    """``n_copies`` independent warps of each image: ``(n, H, W, C)`` ->
    ``(n * n_copies, H, W, C)``."""
    n = images.shape[0]
    keys = jax.random.split(key, n * n_copies).reshape(n_copies, n, -1)
    out = jax.vmap(lambda ks: jax.vmap(lambda k, im: random_affine(k, im, **kw))(ks, images))(keys)
    return out.reshape((n * n_copies,) + images.shape[1:])


# --------------------------------------------------------------------------
# Full client rebalance (Alg. 2 lines 8-13) -- numpy orchestration around
# jit'd warps, because ragged per-class growth is inherently dynamic-shape.
# --------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


@partial(jax.jit, static_argnames=("shift", "rot", "shear", "zoom", "order"))
def _warp_many(key: Array, images: Array, *, shift=3.0, rot=0.3, shear=0.2,
               zoom=0.15, order=1) -> Array:
    keys = jax.random.split(key, images.shape[0])
    return jax.vmap(lambda k, im: random_affine(
        k, im, shift=shift, rot=rot, shear=shear, zoom=zoom, order=order))(keys, images)


def rebalance_client(key: Array, images: np.ndarray, labels: np.ndarray,
                     n_aug_per_class: np.ndarray, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Apply the server's plan to one client's local dataset.

    Returns the client's dataset with augmentations appended and shuffled
    (Alg. 2 line 13). All of the client's augmentations run as ONE jit'd
    warp over a power-of-two padded stack, so XLA's compile cache is hit
    across clients (a >10x init speedup vs per-class calls).
    """
    images = np.asarray(images)
    labels = np.asarray(labels)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    n_aug = np.asarray(n_aug_per_class)[labels]          # copies per sample
    reps = np.repeat(np.arange(labels.shape[0]), n_aug)  # source index per augmentation
    if reps.size == 0:
        perm = rng.permutation(images.shape[0])
        return images[perm], labels[perm]
    total_pad = _next_pow2(reps.size)
    reps_pad = np.concatenate([reps, rng.choice(reps, total_pad - reps.size)]) \
        if total_pad != reps.size else reps
    aug = np.asarray(_warp_many(key, jnp.asarray(images[reps_pad]), **kw))[:reps.size]
    out_x = np.concatenate([images, aug])
    out_y = np.concatenate([labels, labels[reps]])
    perm = rng.permutation(out_x.shape[0])
    return out_x[perm], out_y[perm]


# --------------------------------------------------------------------------
# Online (in-round) augmentation -- the device-resident Alg. 2 pipeline.
# Everything below is jit-native with static shapes: it runs INSIDE the
# engine's compiled round program (core/engine.py), once per mediator slot
# per round, so reschedules and rounds never re-trace.
# --------------------------------------------------------------------------

# salt folded into a mediator's round key to derive its augmentation stream
# (independent of the training stream split from the same key). The async
# engine reuses the engine's round-indexed keys for every wave, so a
# mediator's augmentation draw does not depend on which wave runs it --
# which is what keeps S=0 bitwise-identical to the synchronous engine with
# augmentation enabled.
AUG_SALT = 0x617567          # "aug"

WARP_IMPLS = ("auto", "reference", "pallas")


def warp_params(key: Array, n: int, *, shift: float = 3.0, rot: float = 0.3,
                shear: float = 0.2, zoom: float = 0.15):
    """``n`` independent random affine parameter draws: (n,2,2) mats +
    (n,2) translations, the batched form of ``_affine_params``."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _affine_params(
        k, shift=shift, rot=rot, shear=shear, zoom=zoom))(keys)


def warp_batch(key: Array, images: Array, *, impl: str = "auto",
               order: int = 1, **kw) -> Array:
    """One random affine warp of every image in ``(B, H, W, C)``, fused.

    ``impl`` picks the resampler: ``"reference"`` is the vectorized
    ``map_coordinates`` oracle (``kernels/ref.py``), ``"pallas"`` the fused
    one-launch bilinear-warp kernel (``kernels/affine_warp.py``,
    interpret-mode off-TPU), ``"auto"`` resolves to the kernel on TPU and
    the reference elsewhere (interpret-mode Pallas in a hot CPU round loop
    would be strictly slower than XLA's fused gather).
    """
    from repro.kernels import ref as kref
    mats, trans = warp_params(key, images.shape[0], **kw)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "pallas":
        if order != 1:
            raise ValueError("the pallas warp kernel is bilinear (order=1)")
        from repro.kernels import ops as kops
        return kops.affine_warp(images, mats, trans)
    if impl != "reference":
        raise ValueError(f"unknown warp impl {impl!r}; expected one of "
                         f"{WARP_IMPLS}")
    return kref.affine_warp(images, mats, trans, order=order)


def online_augment_batch(key: Array, x: Array, y: Array, mask: Array,
                         plan: Array, *, impl: str = "auto", order: int = 1,
                         **kw) -> tuple[Array, Array]:
    """Fixed-shape class-conditional resample+warp of one padded client batch.

    ``x (pad, H, W, C)`` / ``y (pad,)`` / ``mask (pad,)`` are the client's
    packed slot tensors; ``plan (num_classes,)`` is the server's broadcast
    ``n_aug`` array.  Every output slot draws a source sample from the
    seeded categorical with weights ``mask * (1 + plan[y])`` -- sample
    ``i``'s post-augmentation multiplicity -- and the draw is a warped copy
    with probability ``plan[y] / (1 + plan[y])`` (of the ``1 + n_aug``
    copies of a class-``y`` sample, ``n_aug`` are augmentations).  Hence

    * expected class mixture of the draws == ``planned_counts`` normalized
      (``online_mixture``), exactly;
    * expected warped fraction within class ``y`` == ``n_aug_y/(1+n_aug_y)``,
      matching Alg. 2's originals-plus-copies composition;
    * shapes (and the round trace) are static; the caller's mask is
      returned unchanged semantics-wise (an all-dummy slot stays an exact
      no-op: all weights 0 keeps the loss mask 0 regardless of content).

    Returns ``(x_drawn, y_drawn)``; the mask is unchanged by construction.
    """
    plan_f = jnp.asarray(plan).astype(jnp.float32)
    mult = 1.0 + plan_f[y]                         # per-sample multiplicity
    w = mask * mult
    k_sel, k_flag, k_warp = jax.random.split(key, 3)
    logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), -jnp.inf)
    idx = jax.random.categorical(k_sel, logits, shape=(y.shape[0],))
    # all-padding slot row (dummy client): every logit is -inf and the
    # categorical degenerates -- pin the gather to row 0 (masked anyway)
    idx = jnp.where(jnp.any(w > 0), idx, 0)
    sx = jnp.take(x, idx, axis=0)
    sy = jnp.take(y, idx, axis=0)
    s_mult = 1.0 + plan_f[sy]
    p_aug = (s_mult - 1.0) / s_mult                # n_aug / (1 + n_aug)
    is_aug = jax.random.uniform(k_flag, p_aug.shape) < p_aug
    warped = warp_batch(k_warp, sx, impl=impl, order=order, **kw)
    sel = is_aug.reshape(is_aug.shape + (1,) * (x.ndim - 1))
    return jnp.where(sel, warped, sx), sy


AUG_MODES = (None, "online", "materialized")


class AugPhase(NamedTuple):
    """Resolved Alg. 2 initialization phase (``resolve_aug_mode``)."""
    data: object                    # FederatedDataset (rebuilt if materialized)
    plan: np.ndarray | None         # the server's n_aug array (None = NoAug)
    engine_plan: np.ndarray | None  # plan to hand the round engine (online)
    extra_storage_frac: float       # realized (materialized mode only)
    planned_extra_frac: float       # what materializing would cost
    mode: str | None                # effective mode after the alpha gate


def resolve_aug_mode(data, alpha: float | None, aug_mode: str | None,
                     seed: int) -> AugPhase:
    """Shared trainer-side resolution of the rebalancing phase.

    Both ``AstraeaTrainer`` and ``FedAvgTrainer`` route through here so the
    mode semantics can never drift between them: ``alpha=None`` disables
    augmentation regardless of ``aug_mode``; ``"materialized"`` rebuilds the
    federation up front (keyword ``dataclasses.replace``, never positional);
    ``"online"`` returns the plan for the engine's in-round pipeline.  An
    all-zero online plan (already-balanced federation, or alpha small
    enough that every count rounds to 0 copies) resolves to NO engine plan:
    there is nothing to augment, so the round program must stay the exact
    no-aug executable rather than pay a resample+warp that selects nothing.
    """
    if aug_mode not in AUG_MODES:
        raise ValueError(f"unknown aug_mode {aug_mode!r}; "
                         f"expected one of {AUG_MODES}")
    mode = aug_mode if alpha is not None else None
    if mode is None:
        return AugPhase(data, None, None, 0.0, 0.0, None)
    counts = data.client_counts().sum(axis=0)
    planned = planned_counts(counts, alpha)
    planned_frac = float(planned.sum() / max(counts.sum(), 1.0) - 1.0)
    if mode == "materialized":
        cx, cy, plan, extra = rebalance_federation(
            jax.random.fold_in(jax.random.PRNGKey(seed), 17),
            data.client_images, data.client_labels, data.num_classes, alpha)
        data = dataclasses.replace(data, client_images=cx, client_labels=cy)
        return AugPhase(data, plan, None, extra, planned_frac, mode)
    plan = augmentation_plan(counts, alpha)
    engine_plan = plan if plan.any() else None
    return AugPhase(data, plan, engine_plan, 0.0, planned_frac, mode)


def resolve_engine_plan(phase: AugPhase, adaptive_plan: bool,
                        alpha: float | None
                        ) -> tuple[np.ndarray | None, float | None]:
    """Shared trainer-side adaptive-plan resolution (both trainers route
    through here, like ``resolve_aug_mode``, so the semantics can never
    drift): returns ``(engine_plan, adaptive_aug_alpha)`` for the engine.

    Adaptive mode requires the online pipeline and installs the in-round
    hook even when the *initial* plan is all-zero -- a later cohort may
    drift into needing one -- whereas the static path keeps the zero-plan
    fast path (no hook, exact no-aug executable).
    """
    if not adaptive_plan:
        return phase.engine_plan, None
    if phase.mode != "online":
        raise ValueError("adaptive_plan requires aug_mode='online' with "
                         "alpha set (the plan must live inside the round "
                         "program to be refreshed)")
    return phase.plan, alpha


def rebalance_federation(key: Array, client_images: list[np.ndarray],
                         client_labels: list[np.ndarray], num_classes: int,
                         alpha: float, **kw):
    """End-to-end Alg. 2 over a federation.

    Returns (new_client_images, new_client_labels, plan, extra_storage_frac).
    """
    counts = np.zeros(num_classes)
    for y in client_labels:
        counts += np.bincount(y, minlength=num_classes)
    plan = augmentation_plan(counts, alpha)
    out_x, out_y = [], []
    for i, (x, y) in enumerate(zip(client_images, client_labels)):
        cx, cy = rebalance_client(jax.random.fold_in(key, i), x, y, plan, **kw)
        out_x.append(cx)
        out_y.append(cy)
    before = sum(x.shape[0] for x in client_images)
    after = sum(x.shape[0] for x in out_x)
    return out_x, out_y, plan, (after - before) / max(before, 1)
