"""Synthetic, genuinely-learnable image classification data.

The container is offline (no EMNIST/CINIC-10), so we synthesize a family of
classification tasks with the same *structure*: each class has a smooth
random prototype image; a sample is its prototype under a random affine
distortion plus pixel noise. A small CNN reaches >90% on the balanced
variant, leaving headroom for imbalance effects to be measured -- which is
all the paper's experiments need (DESIGN.md §2).

Generation is numpy (cheap, done once); training consumes jnp arrays.

Million-client scale: ``federation_counts`` draws a K-client federation's
per-client label histograms in one vectorized pass (Dirichlet skew +
batched multinomial -- no sample is ever materialized), and
``StreamingFederation`` wraps them as a lazy *row source* for the
streaming client stores: a client's padded ``(pad, ...)`` x/y/mask rows
are synthesized deterministically on demand from a per-client seed
sequence, so the same client id always yields byte-identical rows no
matter when -- or on which thread -- it is streamed (the spill store's
prefetch-correctness anchor), and total footprint is histograms
(K x C ints) plus the <= c clients in flight, never K x samples.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 20
    image_size: int = 28
    channels: int = 1
    noise: float = 0.25          # pixel noise std
    distort: float = 0.15        # affine distortion strength
    prototype_freqs: int = 3     # low-frequency components per prototype


def _prototypes(spec: SyntheticSpec, rng: np.random.Generator) -> np.ndarray:
    """Smooth per-class prototypes: random low-frequency Fourier mixtures."""
    h = spec.image_size
    yy, xx = np.mgrid[0:h, 0:h] / h
    protos = np.zeros((spec.num_classes, h, h, spec.channels), np.float32)
    for c in range(spec.num_classes):
        for ch in range(spec.channels):
            img = np.zeros((h, h))
            for _ in range(spec.prototype_freqs):
                fy, fx = rng.integers(1, 4, 2)
                phase_y, phase_x = rng.uniform(0, 2 * np.pi, 2)
                amp = rng.uniform(0.5, 1.0)
                img += amp * np.sin(2 * np.pi * fy * yy + phase_y) * np.cos(2 * np.pi * fx * xx + phase_x)
            protos[c, :, :, ch] = img / np.abs(img).max()
    return protos


def _random_affine_np(rng: np.random.Generator, img: np.ndarray, strength: float) -> np.ndarray:
    """Cheap affine distortion: small rotation + shift via index remap."""
    h = img.shape[0]
    theta = rng.uniform(-strength, strength)
    tx, ty = rng.uniform(-strength * h * 0.2, strength * h * 0.2, 2)
    c, s = np.cos(theta), np.sin(theta)
    yy, xx = np.mgrid[0:h, 0:h].astype(np.float32)
    cy = cx = (h - 1) / 2
    src_y = c * (yy - cy) - s * (xx - cx) + cy + ty
    src_x = s * (yy - cy) + c * (xx - cx) + cx + tx
    iy = np.clip(np.rint(src_y).astype(int), 0, h - 1)
    ix = np.clip(np.rint(src_x).astype(int), 0, h - 1)
    return img[iy, ix]


class SyntheticTask:
    """Holds the class prototypes; generates arbitrarily many fresh samples."""

    def __init__(self, spec: SyntheticSpec, seed: int = 0):
        self.spec = spec
        self._proto_rng = np.random.default_rng(seed)
        self.prototypes = _prototypes(spec, self._proto_rng)

    def sample(self, cls: int, n: int, rng: np.random.Generator) -> np.ndarray:
        proto = self.prototypes[cls]
        out = np.empty((n,) + proto.shape, np.float32)
        for i in range(n):
            img = _random_affine_np(rng, proto, self.spec.distort)
            out[i] = img + rng.normal(0, self.spec.noise, proto.shape)
        return out

    def sample_counts(self, counts: np.ndarray, rng: np.random.Generator
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Generate ``counts[c]`` samples per class, shuffled."""
        xs, ys = [], []
        for c, n in enumerate(np.asarray(counts, int)):
            if n <= 0:
                continue
            xs.append(self.sample(c, int(n), rng))
            ys.append(np.full(int(n), c, np.int32))
        x = np.concatenate(xs) if xs else np.empty((0,) + self.prototypes.shape[1:], np.float32)
        y = np.concatenate(ys) if ys else np.empty((0,), np.int32)
        perm = rng.permutation(x.shape[0])
        return x[perm], y[perm]


def make_classification_data(spec: SyntheticSpec, counts: np.ndarray, seed: int = 0
                             ) -> tuple[np.ndarray, np.ndarray]:
    task = SyntheticTask(spec, seed)
    rng = np.random.default_rng(seed + 1)
    return task.sample_counts(counts, rng)


def federation_counts(num_clients: int, num_classes: int, *,
                      min_samples: int = 24, max_samples: int = 48,
                      skew: float = 0.3, seed: int = 0) -> np.ndarray:
    """``(K, C)`` per-client label histograms, no samples materialized.

    One vectorized pass: per-client totals are uniform ints, per-client
    class mixes are Dirichlet draws (small ``skew`` = non-IID clients
    concentrated on a few classes, the paper's BAL2-style local
    imbalance), and the histograms are a single batched multinomial.
    K=1e6 takes a couple of seconds and ~K * C * 4 bytes -- this is the
    ONLY per-federation state the streaming pipeline keeps.
    """
    rng = np.random.default_rng(seed)
    totals = rng.integers(min_samples, max_samples + 1, num_clients)
    mixes = rng.dirichlet(np.full(num_classes, skew), size=num_clients)
    return rng.multinomial(totals, mixes).astype(np.int32)


# per-client seed-sequence salt, so client streams never collide with the
# federation-level rngs above
_CLIENT_SALT = 0x5F


class StreamingFederation:
    """Lazy K-client federation: histograms up front, samples on demand.

    Implements both surfaces the streaming engine path needs:

    * the *dataset* surface (``num_clients`` / ``num_classes`` /
      ``client_counts()`` / ``pad`` / ``test_images`` / ``test_labels``)
      consumed by ``FLRoundEngine`` for scheduling and eval;
    * the *row source* protocol (``row_specs`` / ``nbytes_per_client`` /
      ``rows(ids)``) consumed by the host/spilled client stores: a
      client's padded x/y/mask rows, synthesized from
      ``SeedSequence([seed, salt, client_id])`` -- deterministic per id,
      independent of streaming order and thread.

    Only the small balanced test set is ever materialized.
    """

    def __init__(self, spec: SyntheticSpec, counts: np.ndarray, *,
                 batch_size: int = 10, seed: int = 0,
                 test_per_class: int = 8, name: str = "stream"):
        self.spec, self.name = spec, name
        self.task = SyntheticTask(spec, seed)
        self._counts = np.asarray(counts)
        self.num_clients, self.num_classes = self._counts.shape
        if self.num_classes != spec.num_classes:
            raise ValueError(f"counts have {self.num_classes} classes, "
                             f"spec has {spec.num_classes}")
        sizes = self._counts.sum(axis=1)
        if sizes.min(initial=1) < 1:
            raise ValueError("every client needs at least one sample")
        # same padding rule as the engine applies to packed federations,
        # so a materialized copy of this federation packs byte-identically
        self.pad = int(-(-int(sizes.max()) // batch_size) * batch_size)
        self._seed = seed
        h = spec.image_size
        self._img_shape = (h, h, spec.channels)
        rng = np.random.default_rng(seed + 1)
        self.test_images, self.test_labels = self.task.sample_counts(
            np.full(self.num_classes, test_per_class), rng)

    def client_counts(self) -> np.ndarray:
        return self._counts

    # ---- row source protocol (core/client_store.py) ----
    @property
    def row_specs(self) -> tuple:
        return (((self.pad,) + self._img_shape, np.dtype(np.float32)),
                ((self.pad,), np.dtype(np.int32)),
                ((self.pad,), np.dtype(np.float32)))

    @property
    def nbytes_per_client(self) -> int:
        return sum(int(np.prod(shape)) * dtype.itemsize
                   for shape, dtype in self.row_specs)

    def _client_rows(self, k: int) -> tuple:
        rng = np.random.default_rng(
            np.random.SeedSequence([self._seed, _CLIENT_SALT, int(k)]))
        x, y = self.task.sample_counts(self._counts[k], rng)
        n = x.shape[0]
        xs = np.zeros((self.pad,) + self._img_shape, np.float32)
        ys = np.zeros((self.pad,), np.int32)
        ms = np.zeros((self.pad,), np.float32)
        xs[:n], ys[:n], ms[:n] = x, y, 1.0
        return xs, ys, ms

    def rows(self, ids: np.ndarray) -> tuple:
        ids = np.asarray(ids)
        out = tuple(np.empty((ids.size,) + shape, dtype)
                    for shape, dtype in self.row_specs)
        for i, k in enumerate(ids):
            for buf, row in zip(out, self._client_rows(int(k))):
                buf[i] = row
        return out

    # ---- equivalence helper (tests / small-K benches) ----
    def materialize(self):
        """Realize the whole federation as a packed ``FederatedDataset``
        -- identical samples to what streaming yields per client, so an
        engine over the materialized copy (any store policy) is bitwise
        identical to the streaming engine. Small K only, obviously."""
        from repro.data.federated import FederatedDataset
        xs, ys = [], []
        for k in range(self.num_clients):
            x, y, m = self._client_rows(k)
            n = int(m.sum())
            xs.append(x[:n].copy())
            ys.append(y[:n].copy())
        return FederatedDataset(client_images=xs, client_labels=ys,
                                test_images=self.test_images,
                                test_labels=self.test_labels,
                                num_classes=self.num_classes,
                                name=self.name + "-materialized")
