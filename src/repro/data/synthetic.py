"""Synthetic, genuinely-learnable image classification data.

The container is offline (no EMNIST/CINIC-10), so we synthesize a family of
classification tasks with the same *structure*: each class has a smooth
random prototype image; a sample is its prototype under a random affine
distortion plus pixel noise. A small CNN reaches >90% on the balanced
variant, leaving headroom for imbalance effects to be measured -- which is
all the paper's experiments need (DESIGN.md §2).

Generation is numpy (cheap, done once); training consumes jnp arrays.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 20
    image_size: int = 28
    channels: int = 1
    noise: float = 0.25          # pixel noise std
    distort: float = 0.15        # affine distortion strength
    prototype_freqs: int = 3     # low-frequency components per prototype


def _prototypes(spec: SyntheticSpec, rng: np.random.Generator) -> np.ndarray:
    """Smooth per-class prototypes: random low-frequency Fourier mixtures."""
    h = spec.image_size
    yy, xx = np.mgrid[0:h, 0:h] / h
    protos = np.zeros((spec.num_classes, h, h, spec.channels), np.float32)
    for c in range(spec.num_classes):
        for ch in range(spec.channels):
            img = np.zeros((h, h))
            for _ in range(spec.prototype_freqs):
                fy, fx = rng.integers(1, 4, 2)
                phase_y, phase_x = rng.uniform(0, 2 * np.pi, 2)
                amp = rng.uniform(0.5, 1.0)
                img += amp * np.sin(2 * np.pi * fy * yy + phase_y) * np.cos(2 * np.pi * fx * xx + phase_x)
            protos[c, :, :, ch] = img / np.abs(img).max()
    return protos


def _random_affine_np(rng: np.random.Generator, img: np.ndarray, strength: float) -> np.ndarray:
    """Cheap affine distortion: small rotation + shift via index remap."""
    h = img.shape[0]
    theta = rng.uniform(-strength, strength)
    tx, ty = rng.uniform(-strength * h * 0.2, strength * h * 0.2, 2)
    c, s = np.cos(theta), np.sin(theta)
    yy, xx = np.mgrid[0:h, 0:h].astype(np.float32)
    cy = cx = (h - 1) / 2
    src_y = c * (yy - cy) - s * (xx - cx) + cy + ty
    src_x = s * (yy - cy) + c * (xx - cx) + cx + tx
    iy = np.clip(np.rint(src_y).astype(int), 0, h - 1)
    ix = np.clip(np.rint(src_x).astype(int), 0, h - 1)
    return img[iy, ix]


class SyntheticTask:
    """Holds the class prototypes; generates arbitrarily many fresh samples."""

    def __init__(self, spec: SyntheticSpec, seed: int = 0):
        self.spec = spec
        self._proto_rng = np.random.default_rng(seed)
        self.prototypes = _prototypes(spec, self._proto_rng)

    def sample(self, cls: int, n: int, rng: np.random.Generator) -> np.ndarray:
        proto = self.prototypes[cls]
        out = np.empty((n,) + proto.shape, np.float32)
        for i in range(n):
            img = _random_affine_np(rng, proto, self.spec.distort)
            out[i] = img + rng.normal(0, self.spec.noise, proto.shape)
        return out

    def sample_counts(self, counts: np.ndarray, rng: np.random.Generator
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Generate ``counts[c]`` samples per class, shuffled."""
        xs, ys = [], []
        for c, n in enumerate(np.asarray(counts, int)):
            if n <= 0:
                continue
            xs.append(self.sample(c, int(n), rng))
            ys.append(np.full(int(n), c, np.int32))
        x = np.concatenate(xs) if xs else np.empty((0,) + self.prototypes.shape[1:], np.float32)
        y = np.concatenate(ys) if ys else np.empty((0,), np.int32)
        perm = rng.permutation(x.shape[0])
        return x[perm], y[perm]


def make_classification_data(spec: SyntheticSpec, counts: np.ndarray, seed: int = 0
                             ) -> tuple[np.ndarray, np.ndarray]:
    task = SyntheticTask(spec, seed)
    rng = np.random.default_rng(seed + 1)
    return task.sample_counts(counts, rng)
