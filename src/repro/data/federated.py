"""Federated partitioners reproducing the paper's TABLE I settings.

Three orthogonal imbalance knobs (Section II-B):

* **Scalar (size)**: per-client dataset sizes -- ``even`` or ``instagram``
  (the cited Instagram-uploads dynamics are heavy-tailed; we use a log-normal
  size law, the standard fit for user-upload counts).
* **Global**: union class distribution -- ``balanced``, ``letterfreq``
  (English letter frequency, the paper's LTRF), or ``normal`` (standard
  normal pdf over class index, the paper's imbalanced CINIC-10).
* **Local**: per-client class distribution -- ``matched`` (each client
  mirrors the global distribution; BAL1) or ``random`` (Dirichlet around the
  global distribution; BAL2/INS/LTRF -- non-IID).

The five TABLE I datasets are then:

    BAL1  = (even,      balanced,   matched)
    BAL2  = (even,      balanced,   random)
    INS   = (instagram, balanced,   random)
    LTRF1 = (instagram, letterfreq, random)
    LTRF2 = LTRF1 with 2x total training data

Clients never share samples (every sample is freshly generated) and the test
set is always balanced -- both paper invariants.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.synthetic import SyntheticSpec, SyntheticTask

# English letter relative frequencies (Wikipedia corpus order a..z), the
# paper's LTRF global law. Truncated + renormalized to num_classes.
_LETTER_FREQ = np.array([
    8.167, 1.492, 2.782, 4.253, 12.702, 2.228, 2.015, 6.094, 6.966, 0.153,
    0.772, 4.025, 2.406, 6.749, 7.507, 1.929, 0.095, 5.987, 6.327, 9.056,
    2.758, 0.978, 2.360, 0.150, 1.974, 0.074])


def letter_frequency_probs(num_classes: int) -> np.ndarray:
    """LTRF global class distribution (sorted descending like Zipf-ish data)."""
    freqs = _LETTER_FREQ
    if num_classes <= len(freqs):
        p = np.sort(freqs)[::-1][:num_classes]
    else:  # extend with a Zipf tail for >26 classes (e.g. 47-class EMNIST)
        tail = freqs.min() / np.arange(2, num_classes - len(freqs) + 2)
        p = np.concatenate([np.sort(freqs)[::-1], tail])[:num_classes]
    return p / p.sum()


def normal_pdf_probs(num_classes: int) -> np.ndarray:
    """Imbalanced CINIC-10: class counts follow the standard normal pdf."""
    z = np.linspace(-2.0, 2.0, num_classes)
    p = np.exp(-0.5 * z * z)
    return p / p.sum()


def instagram_sizes(num_clients: int, rng: np.random.Generator,
                    sigma: float = 1.0) -> np.ndarray:
    """Heavy-tailed per-client size weights (log-normal upload law)."""
    w = rng.lognormal(mean=0.0, sigma=sigma, size=num_clients)
    return w / w.sum()


@dataclass
class FederatedDataset:
    """Per-client padded arrays + masks, ready for jit'd FL simulation."""
    client_images: list[np.ndarray]
    client_labels: list[np.ndarray]
    test_images: np.ndarray
    test_labels: np.ndarray
    num_classes: int
    name: str = "fed"

    @property
    def num_clients(self) -> int:
        return len(self.client_images)

    def client_counts(self) -> np.ndarray:
        out = np.zeros((self.num_clients, self.num_classes))
        for k, y in enumerate(self.client_labels):
            out[k] = np.bincount(y, minlength=self.num_classes)
        return out

    def padded(self, pad_to: int | None = None):
        """Stack clients into (K, pad, ...) arrays + (K, pad) masks.

        Raises ``ValueError`` if ``pad_to`` is smaller than the largest
        client -- silently truncating samples would corrupt the federation
        (the old behavior dropped the tail without warning).
        """
        sizes = [x.shape[0] for x in self.client_images]
        pad = pad_to or max(sizes)
        if pad < max(sizes):
            raise ValueError(
                f"pad_to={pad} would truncate clients: the largest client "
                f"holds {max(sizes)} samples; pass pad_to >= {max(sizes)}")
        sample_shape = self.client_images[0].shape[1:]
        xs = np.zeros((self.num_clients, pad) + sample_shape, np.float32)
        ys = np.zeros((self.num_clients, pad), np.int32)
        mask = np.zeros((self.num_clients, pad), np.float32)
        for k, (x, y) in enumerate(zip(self.client_images, self.client_labels)):
            n = x.shape[0]
            xs[k, :n] = x
            ys[k, :n] = y
            mask[k, :n] = 1.0
        return xs, ys, mask


# dataset presets (scaled-down analogues; see DESIGN.md §2)
EMNIST_LIKE = SyntheticSpec(num_classes=20, image_size=28, channels=1)
CINIC_LIKE = SyntheticSpec(num_classes=10, image_size=32, channels=3)


def _client_class_counts(rng: np.random.Generator, num_clients: int,
                         total_samples: int, global_probs: np.ndarray,
                         size_weights: np.ndarray, local: str,
                         dirichlet_conc: float = 2.0) -> np.ndarray:
    """Integer (K, C) per-client class counts realizing all three knobs."""
    num_classes = global_probs.shape[0]
    sizes = np.maximum(np.rint(size_weights * total_samples).astype(int), 2)
    counts = np.zeros((num_clients, num_classes), int)
    for k in range(num_clients):
        if local == "matched":
            q = global_probs
        elif local == "random":
            q = rng.dirichlet(dirichlet_conc * num_classes * global_probs)
        else:
            raise ValueError(f"unknown local distribution {local!r}")
        counts[k] = rng.multinomial(sizes[k], q)
    return counts


def partition(spec: SyntheticSpec, *, num_clients: int, total_samples: int,
              test_samples: int, sizes: str = "even", global_dist: str = "balanced",
              local: str = "random", seed: int = 0, name: str = "fed",
              dirichlet_conc: float = 2.0) -> FederatedDataset:
    """Build one of the TABLE I-style federated datasets."""
    rng = np.random.default_rng(seed)
    task = SyntheticTask(spec, seed=seed)

    if global_dist == "balanced":
        gp = np.full(spec.num_classes, 1.0 / spec.num_classes)
    elif global_dist == "letterfreq":
        gp = letter_frequency_probs(spec.num_classes)
    elif global_dist == "normal":
        gp = normal_pdf_probs(spec.num_classes)
    else:
        raise ValueError(f"unknown global distribution {global_dist!r}")

    if sizes == "even":
        sw = np.full(num_clients, 1.0 / num_clients)
    elif sizes == "instagram":
        sw = instagram_sizes(num_clients, rng)
    else:
        raise ValueError(f"unknown size law {sizes!r}")

    counts = _client_class_counts(rng, num_clients, total_samples, gp, sw, local,
                                  dirichlet_conc)
    client_x, client_y = [], []
    for k in range(num_clients):
        x, y = task.sample_counts(counts[k], rng)
        client_x.append(x)
        client_y.append(y)

    # balanced test set (paper invariant)
    per_class = test_samples // spec.num_classes
    tx, ty = task.sample_counts(np.full(spec.num_classes, per_class), rng)
    return FederatedDataset(client_x, client_y, tx, ty, spec.num_classes, name)


def table1(spec: SyntheticSpec = EMNIST_LIKE, *, num_clients: int = 60,
           total_samples: int = 6000, test_samples: int = 2000, seed: int = 0
           ) -> dict[str, FederatedDataset]:
    """All five TABLE I datasets at the scaled-down size."""
    mk = lambda name, sizes, gd, local, total: partition(
        spec, num_clients=num_clients, total_samples=total,
        test_samples=test_samples, sizes=sizes, global_dist=gd, local=local,
        seed=seed, name=name)
    return {
        "BAL1": mk("BAL1", "even", "balanced", "matched", total_samples),
        "BAL2": mk("BAL2", "even", "balanced", "random", total_samples),
        "INS": mk("INS", "instagram", "balanced", "random", total_samples),
        "LTRF1": mk("LTRF1", "instagram", "letterfreq", "random", total_samples),
        "LTRF2": mk("LTRF2", "instagram", "letterfreq", "random", total_samples * 2),
    }
