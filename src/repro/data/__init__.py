from repro.data.synthetic import SyntheticSpec, make_classification_data
from repro.data.federated import (FederatedDataset, partition, EMNIST_LIKE, CINIC_LIKE,
                                  letter_frequency_probs, normal_pdf_probs,
                                  instagram_sizes)

__all__ = ["SyntheticSpec", "make_classification_data", "FederatedDataset",
           "partition", "EMNIST_LIKE", "CINIC_LIKE", "letter_frequency_probs",
           "normal_pdf_probs", "instagram_sizes"]
