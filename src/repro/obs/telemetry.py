"""The ``telemetry=`` handle: tracer + registry + the no-op default.

``Telemetry`` is what engines, trainers and stores accept. It bundles a
``Tracer`` (span timeline) with a ``MetricsRegistry`` (per-round
counters/gauges/histograms) and knows how to absorb the engine's scattered
measurement surfaces once per round:

* both ``CommMeter`` ledgers and their breakdown counters (WAN bytes are
  mirrored with ``set_total`` so the Prometheus sample equals
  ``CommMeter.total_bytes`` exactly);
* ``ClientStore.stats()`` (unified schema: every numeric key becomes an
  ``astraea_store_*`` metric with no per-policy branching);
* scheduler stats (KLD mean/max, cross-shard fetch counts);
* engine health: ``num_round_traces`` plus the engine's ``trace_log``
  retrace *reasons* (anything past the first trace per entry point);
* the async engine's staleness distribution, wave timings and commits.

**Off by default, and off means zero.** ``as_telemetry(None)`` returns
``NULL_TELEMETRY``, whose spans are a reused no-op context manager and
whose observe hooks return immediately: no clock reads, no
``block_until_ready``, no attribute formatting. Nothing in this module
runs inside jit, so telemetry on-vs-off is bitwise identical in
trajectories and adds zero round traces -- the invariant pinned by
``tests/test_telemetry.py``.
"""
from __future__ import annotations

import os
from typing import Any

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

#: histogram bucket layouts (documented in obs/README.md)
STALENESS_BUCKETS = (0, 1, 2, 4, 8)
SECONDS_BUCKETS = (0.001, 0.01, 0.1, 1.0, 10.0, 60.0)


class _NullSpan:
    """Reused no-op span: the telemetry-off fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def set(self, **attrs):
        return self

    def sync_on(self, value):
        return self

    duration_s = 0.0


_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """Do-nothing stand-in carrying the full ``Telemetry`` surface."""

    enabled = False
    tracer = None
    metrics = None

    def span(self, name, **attrs):
        return _NULL_SPAN

    def instant(self, name, **attrs):
        return None

    def observe_round(self, engine, *, duration_s=None):
        return None

    def observe_async_round(self, aengine, *, duration_s=None):
        return None

    def flush(self):
        return {}


NULL_TELEMETRY = NullTelemetry()


def as_telemetry(handle) -> "Telemetry | NullTelemetry":
    """Normalize the optional ``telemetry=`` argument: ``None``/``False``
    mean off (the shared no-op singleton), a handle passes through."""
    if handle is None or handle is False:
        return NULL_TELEMETRY
    return handle


class Telemetry:
    """Enabled telemetry: host-side spans + per-round metric absorption.

    ``trace_dir`` (optional) is where ``flush()`` writes the artifacts:
    ``events.jsonl``, ``trace.json`` (Chrome/Perfetto), ``metrics.jsonl``
    (per-round timeline) and ``metrics.prom`` (Prometheus text).
    ``profile=True`` turns on the ``jax.profiler.TraceAnnotation``
    pass-through; ``clock`` is injectable for deterministic tests.
    """

    enabled = True

    def __init__(self, trace_dir: str | None = None, *,
                 profile: bool = False, clock=None):
        self.trace_dir = trace_dir
        self.tracer = Tracer(clock=clock, profile=profile)
        self.metrics = MetricsRegistry()
        self._absorbed_commits = 0

    # ---- tracing passthrough ----
    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def instant(self, name: str, **attrs) -> None:
        self.tracer.instant(name, **attrs)

    # ---- per-round absorption ----
    def observe_round(self, engine, *, duration_s: float | None = None):
        """Absorb the sync engine's measurement surfaces after a round
        (the async wrapper calls this too, then adds its own)."""
        m = self.metrics
        m.counter("astraea_rounds_total",
                  "synchronization rounds completed").set_total(engine._round)
        ledger_help = {
            "wan_bytes_total":
                "WAN ledger: client<->server bytes (CommMeter.total_bytes)",
            "wan_full_delta_bytes_total":
                "WAN exchange legs shipped full-size (no adapter mapping)",
            "wan_adapter_bytes_total":
                "WAN exchange legs shipped as LoRA adapter state",
            "wan_adapter_full_equiv_bytes_total":
                "full-size counterfactual of the adapter exchange legs",
            "intra_pod_bytes_total":
                "datacenter ledger (CommMeter.intra_pod_bytes)",
            "model_axis_tp_bytes_total":
                "2-D mesh tensor-parallel gather bytes",
            "store_stream_bytes_total":
                "host->device client-store streaming bytes",
            "store_exchange_bytes_total":
                "sharded-store serve exchange bytes",
        }
        for key, total in engine.comm.ledger_totals().items():
            m.counter(f"astraea_{key}",
                      ledger_help.get(key, "CommMeter cumulative ledger")
                      ).set_total(total)
        ratio = engine.comm.adapter_reduction_ratio
        if ratio is not None:
            # the scrapeable adapter-vs-full WAN reduction (bytes shipped /
            # full-size counterfactual of the same legs)
            m.gauge("astraea_wan_adapter_reduction_ratio",
                    "LoRA adapter WAN bytes over their full-delta "
                    "equivalent").set(ratio)
        m.gauge("astraea_round_traces",
                "round executable (re)compilations -- must stay 1"
                ).set(engine.num_round_traces)
        m.counter("astraea_schedule_packs_total",
                  "host schedule packing events"
                  ).set_total(engine.num_schedule_packs)
        retraces = [t for t in getattr(engine, "trace_log", [])
                    if t["reason"] != "initial"]
        m.gauge("astraea_unexpected_retraces",
                "round/wave traces beyond the first per entry point"
                ).set(len(retraces))
        stats = engine.last_schedule_stats or {}
        for key in ("kld_mean", "kld_max", "kld_median", "kld_min",
                    "num_mediators"):
            if key in stats:
                m.gauge(f"astraea_schedule_{key}").set(stats[key])
        for key, value in stats.items():
            # satellite fix in engine._pack_schedule namespaces the store
            # placement keys as store_*; mirror the numeric ones
            if key.startswith("store_") and isinstance(value, (int, float)) \
                    and not isinstance(value, bool):
                m.gauge(f"astraea_{key}").set(value)
        for key, value in engine.store.stats().items():
            if isinstance(value, (int, float)) and not isinstance(value, bool):
                m.gauge(f"astraea_store_{key}",
                        "ClientStore.stats() mirror").set(value)
        if duration_s is not None:
            m.histogram("astraea_round_duration_seconds", SECONDS_BUCKETS,
                        "host wall-clock per round (traced runs only)"
                        ).observe(duration_s)
        return m.end_round(engine._round)

    def observe_async_round(self, aengine, *,
                            duration_s: float | None = None):
        """Absorb the async wrapper's staleness/wave/commit surfaces, then
        the wrapped engine's round surfaces (one JSONL row per round)."""
        m = self.metrics
        m.counter("astraea_commits_total",
                  "server commits folded").set_total(aengine.num_commits)
        m.gauge("astraea_virtual_time",
                "async simulated clock").set(aengine.virtual_time)
        m.gauge("astraea_sync_sim_time",
                "synchronous-barrier baseline on the same fleet"
                ).set(aengine.sync_time)
        stale_hist = m.histogram("astraea_staleness", STALENESS_BUCKETS,
                                 "per-contribution commit staleness s_m")
        folded = m.counter("astraea_commit_folded_rows_total",
                           "mediator rows folded across commits")
        for entry in aengine.commit_log[self._absorbed_commits:]:
            for s in entry["staleness"]:
                stale_hist.observe(s)
            folded.inc(entry["folded_rows"])
        self._absorbed_commits = len(aengine.commit_log)
        if aengine.last_wave_stats:
            ws = aengine.last_wave_stats
            m.gauge("astraea_waves_per_round").set(ws["num_waves"])
            m.gauge("astraea_wave_barrier_time").set(ws["barrier_time"])
            m.gauge("astraea_wave_blocked_time_saved"
                    ).set(ws["blocked_time_saved"])
        # dispatch-pipeline surfaces (overlapped mode; zeros when masked)
        m.gauge("astraea_wave_overlap_frac",
                "fraction of wave dispatches issued while the previous "
                "wave's result was still in flight"
                ).set(aengine.overlap_frac)
        m.gauge("astraea_staleness_bound",
                "staleness bound S governing the next commit (adaptive "
                "EWMA bound when configured, else the fixed knob)"
                ).set(aengine.staleness_bound)
        m.counter("astraea_pipeline_syncs_total",
                  "synchronize() pipeline drains (eval/flush boundaries)"
                  ).set_total(aengine.num_syncs)
        m.counter("astraea_commit_wait_seconds_total",
                  "host wall seconds spent draining the commit pipeline"
                  ).set_total(aengine.wall_commit_wait_s)
        return self.observe_round(aengine.engine, duration_s=duration_s)

    # ---- artifacts ----
    def flush(self) -> dict:
        """Write the four artifacts into ``trace_dir`` (no-op without one).
        Returns ``{artifact_name: path}`` for the files written."""
        if not self.trace_dir:
            return {}
        os.makedirs(self.trace_dir, exist_ok=True)
        paths = {
            "events_jsonl": os.path.join(self.trace_dir, "events.jsonl"),
            "trace_json": os.path.join(self.trace_dir, "trace.json"),
            "metrics_jsonl": os.path.join(self.trace_dir, "metrics.jsonl"),
            "metrics_prom": os.path.join(self.trace_dir, "metrics.prom"),
        }
        self.tracer.write_jsonl(paths["events_jsonl"])
        self.tracer.write_chrome_trace(paths["trace_json"])
        self.metrics.write_jsonl(paths["metrics_jsonl"])
        self.metrics.write_prometheus(paths["metrics_prom"])
        return paths
