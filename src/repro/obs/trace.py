"""Host-side span tracing for the federated round path.

A ``Tracer`` records a tree of **spans** -- named wall-clock intervals
with attributes -- plus zero-duration **instant** events, all on the
host, entirely outside jit. The engine opens spans around its host-side
phases (``round`` > ``reschedule``/``plan_refresh``/``pack`` >
``store_stream``, async ``wave``/``commit``, sync ``aggregate``) so a
round's wall-clock has one navigable timeline instead of being smeared
across ad-hoc prints and bench JSONs.

Two export formats, both derived from the same event list:

* **JSONL** (``events.jsonl``): one JSON object per line, schema-versioned
  (``SCHEMA_VERSION``). Machine-diffable; ``validate_events`` checks the
  schema and the nesting invariants (parents exist, child intervals sit
  inside their parent's interval).
* **Chrome trace** (``trace.json``): the Trace Event Format consumed by
  Perfetto / ``chrome://tracing`` -- complete ``"X"`` events with ``ts``/
  ``dur`` in microseconds.

Device-sync discipline: a span only calls ``jax.block_until_ready`` on
values explicitly registered via ``Span.sync_on`` and only at span close
-- so timings are honest (the async dispatch queue is drained before the
clock is read) but NOTHING is blocked on when tracing is off: the no-op
telemetry path (``obs.telemetry.NULL_TELEMETRY``) never touches a device
value, which is what keeps telemetry-off rounds free of extra syncs.

Optional ``jax.profiler`` pass-through: ``Tracer(profile=True)`` wraps
every span in ``jax.profiler.TraceAnnotation`` so XLA device traces line
up with the host spans, and ``start_device_trace``/``stop_device_trace``
bracket a run with ``jax.profiler.start_trace`` when the backend supports
it (best-effort: failures degrade to host-only tracing, never raise).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable

#: bump when the JSONL event schema changes shape (validators pin this)
SCHEMA_VERSION = 1

#: keys every JSONL event must carry
EVENT_KEYS = ("schema", "kind", "id", "parent", "name", "ts_us", "dur_us",
              "attrs")


class Span:
    """One open interval; use as a context manager via ``Tracer.span``."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "t0", "t1",
                 "_tracer", "_sync", "_annotation")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: int | None, attrs: dict):
        self.name, self.attrs = name, dict(attrs)
        self.span_id, self.parent_id = span_id, parent_id
        self._tracer = tracer
        self._sync: list[Any] = []
        self._annotation = None
        self.t0 = self.t1 = 0.0

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (KLD mean, bytes, ...)."""
        self.attrs.update(attrs)
        return self

    def sync_on(self, value: Any) -> "Span":
        """Register a (pytree of) device value(s) to ``block_until_ready``
        at span close, so the span's duration includes the device work it
        dispatched. Only ever called with tracing enabled."""
        self._sync.append(value)
        return self

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def __enter__(self) -> "Span":
        tracer = self._tracer
        if tracer.profile:
            self._annotation = tracer._annotate(self.name)
            if self._annotation is not None:
                self._annotation.__enter__()
        tracer._stack.append(self.span_id)
        self.t0 = tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._sync:
            import jax
            jax.block_until_ready(self._sync)
        tracer = self._tracer
        self.t1 = tracer.clock()
        assert tracer._stack and tracer._stack[-1] == self.span_id, \
            "span close out of order (spans must nest)"
        tracer._stack.pop()
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
            self._annotation = None
        tracer._emit(self)


class Tracer:
    """Append-only span/instant recorder with JSONL + Chrome-trace export.

    ``clock`` defaults to ``time.perf_counter`` (monotonic); tests inject
    a fake clock for deterministic timestamps. ``profile=True`` turns on
    the ``jax.profiler.TraceAnnotation`` pass-through.
    """

    def __init__(self, *, clock: Callable[[], float] | None = None,
                 profile: bool = False):
        self.clock = clock or time.perf_counter
        self.profile = profile
        self.events: list[dict] = []
        self._stack: list[int] = []
        self._next_id = 0
        self._epoch = self.clock()

    # ---- recording ----
    def span(self, name: str, **attrs) -> Span:
        sid, self._next_id = self._next_id, self._next_id + 1
        parent = self._stack[-1] if self._stack else None
        return Span(self, name, sid, parent, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Zero-duration marker (e.g. a ledger charge) at the current
        nesting level."""
        sid, self._next_id = self._next_id, self._next_id + 1
        now = self.clock()
        self.events.append(self._event("instant", sid,
                                       self._stack[-1] if self._stack
                                       else None,
                                       name, now, now, attrs))

    def _emit(self, span: Span) -> None:
        self.events.append(self._event("span", span.span_id, span.parent_id,
                                       span.name, span.t0, span.t1,
                                       span.attrs))

    def _event(self, kind, sid, parent, name, t0, t1, attrs) -> dict:
        return {"schema": SCHEMA_VERSION, "kind": kind, "id": sid,
                "parent": parent, "name": name,
                "ts_us": (t0 - self._epoch) * 1e6,
                "dur_us": (t1 - t0) * 1e6,
                "attrs": _jsonable(attrs)}

    def _annotate(self, name: str):
        try:
            import jax.profiler
            return jax.profiler.TraceAnnotation(name)
        except Exception:        # profiler unavailable: host spans only
            return None

    # ---- export ----
    def to_jsonl(self) -> str:
        return "".join(json.dumps(e, sort_keys=True) + "\n"
                       for e in self.events)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    def to_chrome_trace(self) -> dict:
        """Trace Event Format: complete ``"X"`` events, us timestamps --
        loadable in Perfetto / chrome://tracing as-is."""
        trace_events = []
        for e in self.events:
            trace_events.append({
                "name": e["name"], "cat": "astraea",
                "ph": "X" if e["kind"] == "span" else "i",
                "ts": e["ts_us"], "dur": e["dur_us"],
                "pid": 0, "tid": 0,
                "args": dict(e["attrs"], event_id=e["id"],
                             parent=e["parent"]),
            })
        return {"traceEvents": trace_events, "displayTimeUnit": "ms",
                "otherData": {"schema": SCHEMA_VERSION}}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def _jsonable(attrs: dict) -> dict:
    """Coerce numpy / jax scalars so every event round-trips json.dumps."""
    out = {}
    for k, v in attrs.items():
        if hasattr(v, "item") and getattr(v, "ndim", None) in (0, None):
            try:
                v = v.item()
            except Exception:
                v = repr(v)
        elif not isinstance(v, (str, int, float, bool, type(None), list,
                                dict, tuple)):
            v = repr(v)
        out[k] = v
    return out


def validate_events(events: list[dict]) -> None:
    """Raise ``ValueError`` unless ``events`` is a schema-valid span tree.

    Checks: every event carries exactly the schema-versioned key set;
    every ``parent`` id names an emitted span; every child span's
    interval nests inside its parent's. Used by the telemetry tests and
    the CI smoke leg against freshly emitted JSONL.
    """
    spans: dict[int, dict] = {}
    for i, e in enumerate(events):
        missing = [k for k in EVENT_KEYS if k not in e]
        if missing:
            raise ValueError(f"event {i} missing keys {missing}: {e}")
        if e["schema"] != SCHEMA_VERSION:
            raise ValueError(f"event {i} schema {e['schema']} != "
                             f"{SCHEMA_VERSION}")
        if e["kind"] not in ("span", "instant"):
            raise ValueError(f"event {i} bad kind {e['kind']!r}")
        if e["dur_us"] < 0:
            raise ValueError(f"event {i} negative duration")
        if e["kind"] == "span":
            spans[e["id"]] = e
    for e in events:
        p = e["parent"]
        if p is None:
            continue
        if p not in spans:
            raise ValueError(f"event {e['id']} parent {p} never emitted "
                             f"as a span")
        parent = spans[p]
        lo, hi = parent["ts_us"], parent["ts_us"] + parent["dur_us"]
        if not (lo - 1e-3 <= e["ts_us"] and
                e["ts_us"] + e["dur_us"] <= hi + 1e-3):
            raise ValueError(
                f"event {e['id']} ({e['name']}) interval "
                f"[{e['ts_us']}, {e['ts_us'] + e['dur_us']}] escapes "
                f"parent {p} ({parent['name']}) [{lo}, {hi}]")


def load_jsonl(path: str) -> list[dict]:
    """Parse an ``events.jsonl`` file back into the event list."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---- optional XLA device-trace pass-through (best effort) ----
def start_device_trace(log_dir: str) -> bool:
    """Begin a ``jax.profiler`` device trace alongside the host spans.
    Returns False (and stays host-only) when the backend/profiler can't."""
    try:
        import jax.profiler
        jax.profiler.start_trace(log_dir)
        return True
    except Exception:
        return False


def stop_device_trace() -> None:
    try:
        import jax.profiler
        jax.profiler.stop_trace()
    except Exception:
        pass
