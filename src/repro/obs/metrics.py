"""Unified metrics registry: counters / gauges / histograms, one export.

Before this module the repro's measured claims lived on four unrelated
surfaces -- ``CommMeter``'s two ledgers, ``ClientStore.stats()``,
``engine.last_schedule_stats`` and ad-hoc bench JSON -- each with its own
spelling. ``MetricsRegistry`` is the single sink: the telemetry layer
(``obs.telemetry``) writes every one of those surfaces into named metrics
once per round, and the registry exports them two ways:

* **per-round JSONL** (``metrics.jsonl``): one snapshot per round, every
  metric flattened to scalars -- the timeline the experiments doc renders;
* **Prometheus text exposition** (``to_prometheus()``): ``# TYPE``-tagged
  text served by ``launch/metrics_endpoint.py`` for scrape-based
  deployments. Counter samples keep their conventional ``_total`` suffix,
  histograms expand to ``_bucket{le=...}`` / ``_sum`` / ``_count``.

Counters mirror *cumulative* sources (the ``CommMeter`` ledgers are
already monotone running totals), so they support ``set_total`` with a
monotonicity check in addition to ``inc`` -- the exposition value is then
**exactly** the ledger value, which is what the acceptance check
"Prometheus WAN bytes == ``CommMeter.total_bytes``" pins.
"""
from __future__ import annotations

import json
import math


class Counter:
    """Monotone cumulative value."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def set_total(self, total: float) -> None:
        """Mirror an external cumulative ledger (must never decrease)."""
        if total < self.value - 1e-9:
            raise ValueError(f"counter {self.name}: set_total({total}) "
                             f"below current {self.value}")
        self.value = total

    def sample(self) -> float:
        return self.value


class Gauge:
    """Point-in-time value (last write wins)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value: float | None = None

    def set(self, value: float) -> None:
        self.value = value

    def sample(self) -> float:
        return 0.0 if self.value is None else self.value


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each ``le``
    bucket counts observations <= its bound, ``+Inf`` counts all)."""

    kind = "histogram"

    def __init__(self, name: str, buckets: tuple = (1, 2, 4, 8, 16),
                 help: str = ""):
        self.name, self.help = name, help
        self.bounds = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.bounds) + 1)      # +Inf last
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.counts[i] += 1
        self.counts[-1] += 1

    def sample(self) -> dict:
        row = {f"le_{_fmt(b)}": c
               for b, c in zip(self.bounds, self.counts)}
        row["le_inf"] = self.counts[-1]
        row["sum"] = self.sum
        row["count"] = self.count
        return row


def _fmt(bound: float) -> str:
    return str(int(bound)) if bound == int(bound) else str(bound)


class MetricsRegistry:
    """Get-or-create registry; one instance per telemetry handle."""

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self.round_rows: list[dict] = []

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help=help, **kwargs)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, buckets: tuple = (1, 2, 4, 8, 16),
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    # ---- export ----
    def snapshot(self) -> dict:
        """Flat dict of every metric's current sample (histograms nest)."""
        return {name: m.sample() for name, m in sorted(self._metrics.items())}

    def end_round(self, round_index: int) -> dict:
        """Snapshot the registry at a round boundary (JSONL timeline)."""
        row = {"round": int(round_index), **self.snapshot()}
        self.round_rows.append(row)
        return row

    def to_jsonl(self) -> str:
        return "".join(json.dumps(r, sort_keys=True) + "\n"
                       for r in self.round_rows)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                acc_name = name
                for b, c in zip(m.bounds, m.counts):
                    lines.append(f'{acc_name}_bucket{{le="{_fmt(b)}"}} {c}')
                lines.append(f'{acc_name}_bucket{{le="+Inf"}} '
                             f"{m.counts[-1]}")
                lines.append(f"{acc_name}_sum {_num(m.sum)}")
                lines.append(f"{acc_name}_count {m.count}")
            else:
                lines.append(f"{name} {_num(m.sample())}")
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())


def _num(v: float) -> str:
    """Exact integers render without a trailing ``.0`` so byte totals
    diff cleanly against the integer ledgers."""
    f = float(v)
    if math.isfinite(f) and f == int(f) and abs(f) < 2 ** 53:
        return str(int(f))
    return repr(f)
