"""Round-trace telemetry: span tracing + unified metrics registry.

See ``obs/README.md`` for the span taxonomy, JSONL schema, Prometheus
metric names, and the off-by-default / zero-retrace contract.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.telemetry import (NULL_TELEMETRY, NullTelemetry,  # noqa: F401
                                 Telemetry, as_telemetry)
from repro.obs.trace import (SCHEMA_VERSION, Span, Tracer,  # noqa: F401
                             load_jsonl, start_device_trace,
                             stop_device_trace, validate_events)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TELEMETRY", "NullTelemetry", "Telemetry", "as_telemetry",
    "SCHEMA_VERSION", "Span", "Tracer", "load_jsonl",
    "start_device_trace", "stop_device_trace", "validate_events",
]
